//! The dynamic batcher: packs `(C, S)` rows into backend dispatches.
//!
//! Rows accumulate in flat buffers; [`Batcher::run`] slices them into
//! chunks of at most `target` rows (and at most the backend's own
//! `max_batch`), preserving order so the fold stage sees deterministic
//! results. [`Batcher::run_pool`] does the same across a
//! [`BackendPool`] — chunks evaluate concurrently on independent backend
//! instances and reassemble in row order, so the output is identical to
//! the serial dispatch for any worker count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::sync::LockExt;

use crate::compute::{BackendPool, SpikeBuf, SpikeRows, StepBackend, StepBatch, StepMode};
use crate::engine::ConfigVector;
use crate::error::Result;

/// Apply one delta row to its parent row with the checked non-negative
/// add (the semantics guarantee it; a violation indicates a backend bug).
fn apply_delta(parent: &[i64], delta: &[i64]) -> Result<ConfigVector> {
    let mut counts = Vec::with_capacity(parent.len());
    for (p, d) in parent.iter().zip(delta) {
        let v = p + d;
        if v < 0 {
            return Err(crate::Error::Coordinator(format!(
                "negative spike count {v} in delta step result"
            )));
        }
        counts.push(v as u64);
    }
    Ok(ConfigVector::new(counts))
}

/// Order-preserving batch accumulator.
pub struct Batcher {
    n: usize,
    r: usize,
    target: usize,
    configs: Vec<i64>,
    spikes: SpikeBuf,
    rows: usize,
    mode: StepMode,
}

impl Batcher {
    /// New batcher for `(R, N)` with a per-dispatch row target (dense
    /// spiking rows).
    pub fn new(n: usize, r: usize, target: usize) -> Self {
        Batcher::with_repr(n, r, target, 0, false)
    }

    /// New batcher with pre-sized buffers for `rows` dense rows.
    pub fn with_capacity(n: usize, r: usize, target: usize, rows: usize) -> Self {
        Batcher::with_repr(n, r, target, rows, false)
    }

    /// New batcher picking the spiking-row representation: sparse rows
    /// accumulate CSR fired-rule lists end-to-end (dispatch slices are
    /// zero-copy windows, no densification anywhere on the host path).
    pub fn with_repr(n: usize, r: usize, target: usize, rows: usize, sparse: bool) -> Self {
        let mut spikes = SpikeBuf::with_repr(sparse, r);
        spikes.reserve_rows(rows, r);
        Batcher {
            n,
            r,
            target: target.max(1),
            configs: Vec::with_capacity(rows * n),
            spikes,
            rows: 0,
            mode: StepMode::Auto,
        }
    }

    /// Pick the stepping mode (default: auto — delta on delta-native
    /// backends). Dispatch results are byte-identical in every mode; the
    /// delta path reuses one buffer per dispatch run instead of taking a
    /// fresh `B × N` vector from the backend per chunk.
    pub fn with_step_mode(mut self, mode: StepMode) -> Self {
        self.mode = mode;
        self
    }

    /// Append pre-built rows (from a worker's expansion); converts only
    /// when the representations differ.
    pub fn push_rows(&mut self, configs: &[i64], spikes: SpikeRows<'_>, rows: usize) {
        debug_assert_eq!(configs.len(), rows * self.n);
        debug_assert_eq!(spikes.num_rows(self.r), rows);
        self.configs.extend_from_slice(configs);
        self.spikes.extend_from(spikes, rows, self.r);
        self.rows += rows;
    }

    /// Append a single row given as dense 0/1 bytes.
    pub fn push(&mut self, config: &ConfigVector, spiking: &[u8]) {
        debug_assert_eq!(config.len(), self.n);
        debug_assert_eq!(spiking.len(), self.r);
        self.configs.extend(config.as_slice().iter().map(|&x| x as i64));
        self.spikes.push_byte_row(spiking);
        self.rows += 1;
    }

    /// Pending rows.
    pub fn len(&self) -> usize {
        self.rows
    }

    /// No rows pending?
    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Dispatch everything; returns `(child configs in row order,
    /// rows evaluated, dispatch count)`.
    pub fn run(self, backend: &mut dyn StepBackend) -> Result<(Vec<ConfigVector>, u64, u64)> {
        let total = self.rows;
        let use_delta = self.mode.use_delta(backend.native_deltas());
        let mut out = Vec::with_capacity(total);
        let mut batches = 0u64;
        let cap = self.target.min(backend.max_batch()).max(1);
        let mut delta_buf: Vec<i64> = Vec::new();
        let mut row = 0usize;
        while row < total {
            let take = (total - row).min(cap);
            let parents = &self.configs[row * self.n..(row + take) * self.n];
            let batch = StepBatch {
                b: take,
                n: self.n,
                r: self.r,
                configs: parents,
                spikes: self.spikes.as_rows().slice(row, row + take, self.r),
            };
            batches += 1;
            if use_delta {
                backend.step_deltas_into(&batch, &mut delta_buf)?;
                for b in 0..take {
                    out.push(apply_delta(
                        &parents[b * self.n..(b + 1) * self.n],
                        &delta_buf[b * self.n..(b + 1) * self.n],
                    )?);
                }
            } else {
                let result = backend.step_batch(&batch)?;
                for b in 0..take {
                    out.push(ConfigVector::from_signed(&result[b * self.n..(b + 1) * self.n])?);
                }
            }
            row += take;
        }
        Ok((out, total as u64, batches))
    }

    /// Dispatch everything across a backend pool: chunks of at most
    /// `target` rows evaluate concurrently on up to `workers` pooled
    /// instances; results reassemble in row order (bit-identical to
    /// [`Batcher::run`] on one instance).
    pub fn run_pool(
        self,
        pool: &BackendPool,
        workers: usize,
    ) -> Result<(Vec<ConfigVector>, u64, u64)> {
        let total = self.rows;
        if total == 0 {
            return Ok((Vec::new(), 0, 0));
        }
        let cap = self.target.min(pool.max_batch()).max(1);
        let chunks = total.div_ceil(cap);
        let workers = workers.min(pool.size()).min(chunks).max(1);
        if workers == 1 {
            let mut backend = pool.acquire();
            return self.run(&mut *backend);
        }
        let use_delta = self.mode.use_delta(pool.native_deltas());
        let mut init: Vec<Option<Result<Vec<ConfigVector>>>> = Vec::new();
        init.resize_with(chunks, || None);
        let slots = Mutex::new(init);
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    let mut backend = pool.acquire();
                    // per-worker reusable delta buffer (delta mode)
                    let mut delta_buf: Vec<i64> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= chunks {
                            break;
                        }
                        let row = i * cap;
                        let take = (total - row).min(cap);
                        let parents = &self.configs[row * self.n..(row + take) * self.n];
                        let batch = StepBatch {
                            b: take,
                            n: self.n,
                            r: self.r,
                            configs: parents,
                            spikes: self.spikes.as_rows().slice(row, row + take, self.r),
                        };
                        let res = if use_delta {
                            backend.step_deltas_into(&batch, &mut delta_buf).and_then(|()| {
                                let mut v = Vec::with_capacity(take);
                                for b in 0..take {
                                    v.push(apply_delta(
                                        &parents[b * self.n..(b + 1) * self.n],
                                        &delta_buf[b * self.n..(b + 1) * self.n],
                                    )?);
                                }
                                Ok(v)
                            })
                        } else {
                            backend.step_batch(&batch).and_then(|out| {
                                let mut v = Vec::with_capacity(take);
                                for b in 0..take {
                                    v.push(ConfigVector::from_signed(
                                        &out[b * self.n..(b + 1) * self.n],
                                    )?);
                                }
                                Ok(v)
                            })
                        };
                        slots.lock_recover()[i] = Some(res);
                    }
                });
            }
        });
        let mut out = Vec::with_capacity(total);
        for slot in slots.into_inner().unwrap_or_else(|e| e.into_inner()) {
            // lint: allow(L1) — the atomic chunk counter hands every index
            // to exactly one worker before the scope joins
            out.extend(slot.expect("every chunk claimed by a worker")?);
        }
        Ok((out, total as u64, chunks as u64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::HostBackend;
    use crate::matrix::build_matrix;

    #[test]
    fn batches_respect_target_and_preserve_order() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let mut batcher = Batcher::new(3, 5, 2);
        let c0 = ConfigVector::from(vec![2, 1, 1]);
        // five identical rows with alternating spiking vectors
        for i in 0..5u32 {
            let s: &[u8] = if i % 2 == 0 { &[1, 0, 1, 1, 0] } else { &[0, 1, 1, 1, 0] };
            batcher.push(&c0, s);
        }
        assert_eq!(batcher.len(), 5);
        let mut backend = HostBackend::new(&m);
        let (out, steps, batches) = batcher.run(&mut backend).unwrap();
        assert_eq!(steps, 5);
        assert_eq!(batches, 3, "ceil(5/2)");
        let names: Vec<String> = out.iter().map(|c| c.to_string()).collect();
        assert_eq!(names, vec!["2-1-2", "1-1-2", "2-1-2", "1-1-2", "2-1-2"]);
    }

    #[test]
    fn empty_batcher_runs_clean() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let batcher = Batcher::new(3, 5, 8);
        assert!(batcher.is_empty());
        let mut backend = HostBackend::new(&m);
        let (out, steps, batches) = batcher.run(&mut backend).unwrap();
        assert!(out.is_empty());
        assert_eq!((steps, batches), (0, 0));
    }

    #[test]
    fn pool_dispatch_matches_serial_dispatch() {
        use crate::compute::{BackendPool, HostBackendFactory};
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let c0 = ConfigVector::from(vec![2, 1, 1]);
        let fill = |batcher: &mut Batcher| {
            for i in 0..23u32 {
                let s: &[u8] = if i % 2 == 0 { &[1, 0, 1, 1, 0] } else { &[0, 1, 1, 1, 0] };
                batcher.push(&c0, s);
            }
        };
        let mut serial = Batcher::new(3, 5, 4);
        fill(&mut serial);
        let mut backend = HostBackend::new(&m);
        let (want, steps, _) = serial.run(&mut backend).unwrap();
        assert_eq!(steps, 23);
        for workers in [1usize, 2, 4] {
            let pool = BackendPool::build(&HostBackendFactory::new(m.clone()), workers).unwrap();
            let mut batcher = Batcher::new(3, 5, 4);
            fill(&mut batcher);
            let (got, steps, batches) = batcher.run_pool(&pool, workers).unwrap();
            assert_eq!(steps, 23);
            assert_eq!(batches, 6, "ceil(23/4)");
            assert_eq!(got, want, "workers={workers}");
        }
    }

    #[test]
    fn push_rows_bulk_matches_push_single() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let c0 = ConfigVector::from(vec![2, 1, 1]);
        let spk = [1u8, 0, 1, 1, 0];
        let mut a = Batcher::new(3, 5, 8);
        a.push(&c0, &spk);
        a.push(&c0, &spk);
        let mut b = Batcher::with_capacity(3, 5, 8, 2);
        let flat_c = [2i64, 1, 1, 2, 1, 1];
        let flat_s = [1u8, 0, 1, 1, 0, 1, 0, 1, 1, 0];
        b.push_rows(&flat_c, crate::compute::SpikeRows::Dense(&flat_s), 2);
        let mut be = HostBackend::new(&m);
        let ra = a.run(&mut be).unwrap();
        let mut be2 = HostBackend::new(&m);
        let rb = b.run(&mut be2).unwrap();
        assert_eq!(ra.0, rb.0);
    }

    #[test]
    fn step_modes_agree_across_dispatch_paths() {
        use crate::compute::{BackendPool, HostBackendFactory};
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let c0 = ConfigVector::from(vec![2, 1, 1]);
        let fill = |batcher: &mut Batcher| {
            for i in 0..19u32 {
                let s: &[u8] = if i % 2 == 0 { &[1, 0, 1, 1, 0] } else { &[0, 1, 1, 1, 0] };
                batcher.push(&c0, s);
            }
        };
        let mut reference = Batcher::new(3, 5, 4).with_step_mode(StepMode::Batch);
        fill(&mut reference);
        let (want, _, _) = reference.run(&mut HostBackend::new(&m)).unwrap();
        for mode in [StepMode::Auto, StepMode::Delta] {
            // serial dispatch
            let mut b = Batcher::new(3, 5, 4).with_step_mode(mode);
            fill(&mut b);
            let (got, steps, _) = b.run(&mut HostBackend::new(&m)).unwrap();
            assert_eq!(steps, 19);
            assert_eq!(got, want, "{mode:?} serial");
            // pooled dispatch
            let pool = BackendPool::build(&HostBackendFactory::new(m.clone()), 3).unwrap();
            assert!(pool.native_deltas());
            let mut b = Batcher::new(3, 5, 4).with_step_mode(mode);
            fill(&mut b);
            let (got, _, _) = b.run_pool(&pool, 3).unwrap();
            assert_eq!(got, want, "{mode:?} pooled");
        }
    }

    #[test]
    fn sparse_batcher_matches_dense_across_dispatch_paths() {
        use crate::compute::{BackendPool, HostBackendFactory};
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let c0 = ConfigVector::from(vec![2, 1, 1]);
        let fill = |batcher: &mut Batcher| {
            for i in 0..17u32 {
                let s: &[u8] = if i % 2 == 0 { &[1, 0, 1, 1, 0] } else { &[0, 1, 1, 1, 0] };
                batcher.push(&c0, s);
            }
        };
        let mut dense = Batcher::new(3, 5, 4);
        fill(&mut dense);
        let mut backend = HostBackend::new(&m);
        let (want, _, _) = dense.run(&mut backend).unwrap();
        // sparse batcher through the serial dispatch
        let mut sparse = Batcher::with_repr(3, 5, 4, 0, true);
        fill(&mut sparse);
        let mut backend = HostBackend::new(&m);
        let (got, steps, _) = sparse.run(&mut backend).unwrap();
        assert_eq!(steps, 17);
        assert_eq!(got, want);
        // sparse batcher through the pool dispatch (sliced CSR windows)
        let pool = BackendPool::build(&HostBackendFactory::new(m), 3).unwrap();
        let mut sparse = Batcher::with_repr(3, 5, 4, 0, true);
        fill(&mut sparse);
        let (got, _, _) = sparse.run_pool(&pool, 3).unwrap();
        assert_eq!(got, want);
    }
}
