//! The coordinator: a multi-worker, batch-dispatching exploration
//! pipeline — the production version of [`crate::engine::Explorer`].
//!
//! Level-synchronous parallel BFS:
//!
//! 1. **Expand** (parallel): the current level is partitioned across
//!    worker threads; each computes applicability and enumerates valid
//!    spiking vectors (paper Algorithm 2) into flat batch buffers.
//! 2. **Step** (parallel, device): the batcher chunks the rows and
//!    dispatches them concurrently across a [`BackendPool`] of
//!    independent step backends (host or XLA/PJRT), one per worker.
//! 3. **Fold** (parallel): results are deduplicated in a sharded visited
//!    store; newly discovered configurations — tagged for deterministic
//!    ordering — form the next level.
//!
//! The result is bit-identical to the single-threaded explorer (same
//! visited set, same BFS level structure) regardless of worker count —
//! asserted by `tests/coordinator_e2e.rs`.

mod batcher;
mod metrics;
mod queue;
mod worker;

pub use batcher::Batcher;
pub use metrics::{LevelMetrics, Metrics};
pub use queue::LevelQueue;
pub use worker::{LevelDriver, LevelOutcome};

use crate::compute::{
    BackendPool, DeltaCache, HostBackend, HostBackendFactory, StepBackend, XlaBackendFactory,
    DEFAULT_DELTA_CACHE,
};
use crate::engine::{ConfigVector, SpillConfig, SpillShared, StopReason, StoreMode, VisitedStore};
use crate::error::Result;
use crate::matrix::{build_matrix, TransitionMatrix};
use crate::snp::SnpSystem;

/// Which backend evaluates step batches.
pub enum BackendChoice {
    /// Pure-Rust host backend.
    Host,
    /// XLA/PJRT device backend over AOT artifacts.
    Xla {
        /// Artifacts directory (containing `manifest.json`).
        artifacts: std::path::PathBuf,
    },
    /// Caller-supplied backend.
    Custom(Box<dyn StepBackend>),
}

impl std::fmt::Debug for BackendChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendChoice::Host => write!(f, "Host"),
            BackendChoice::Xla { artifacts } => write!(f, "Xla({})", artifacts.display()),
            BackendChoice::Custom(_) => write!(f, "Custom"),
        }
    }
}

/// Coordinator configuration.
#[derive(Debug)]
pub struct CoordinatorConfig {
    /// Worker threads for expand/fold (0 = available parallelism).
    pub workers: usize,
    /// Depth bound (None = unbounded).
    pub max_depth: Option<u32>,
    /// Distinct-configuration budget.
    pub max_configs: Option<usize>,
    /// Backend for step evaluation.
    pub backend: BackendChoice,
    /// Target rows per backend dispatch.
    pub batch_target: usize,
    /// Spiking-row representation for expansion/dispatch (auto = pick by
    /// shape; output is identical either way).
    pub spike_repr: crate::compute::SpikeRepr,
    /// Stepping mode for dispatch (auto = delta on delta-native pools;
    /// output is identical either way).
    pub step_mode: crate::compute::StepMode,
    /// Visited-arena storage mode (plain rows, varint parent-delta
    /// compression, or disk-spillable compressed segments; output is
    /// identical either way).
    pub store_mode: StoreMode,
    /// Spill-tier knobs (directory and resident-byte budget); only read
    /// when `store_mode` is [`StoreMode::Spill`].
    pub spill: SpillConfig,
    /// Run-scoped `S → S·M` delta-cache capacity (0 = off).
    pub delta_cache: usize,
    /// Optional span recorder: a `run` span with per-level `level`
    /// spans (each holding `expand`/`step`/`fold` children), plus the
    /// pool's `checkout` and the backends' `delta_cache` events. `None`
    /// (the default) records nothing; output is identical either way.
    pub trace: Option<std::sync::Arc<crate::obs::Trace>>,
    /// Optional cancellation/deadline token, polled between levels and
    /// between windows inside a level. A fired token turns the run into
    /// a structured [`Error::Cancelled`](crate::Error::Cancelled) /
    /// [`Error::DeadlineExceeded`](crate::Error::DeadlineExceeded).
    /// `None` (the default) is a dead branch; output is identical when
    /// an armed token never fires.
    pub cancel: Option<crate::util::CancelToken>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 0,
            max_depth: None,
            max_configs: None,
            backend: BackendChoice::Host,
            batch_target: 256,
            spike_repr: crate::compute::SpikeRepr::Auto,
            step_mode: crate::compute::StepMode::Auto,
            store_mode: StoreMode::Plain,
            spill: SpillConfig::default(),
            delta_cache: DEFAULT_DELTA_CACHE,
            trace: None,
            cancel: None,
        }
    }
}

/// Outcome of a coordinated run.
#[derive(Debug)]
pub struct RunReport {
    /// All distinct configurations in deterministic BFS order.
    pub visited: VisitedStore,
    /// Stop reason.
    pub stop: StopReason,
    /// Halting configurations found.
    pub halting: Vec<ConfigVector>,
    /// Per-level and aggregate metrics.
    pub metrics: Metrics,
}

/// The coordinator.
pub struct Coordinator<'a> {
    sys: &'a SnpSystem,
    matrix: TransitionMatrix,
    cfg: CoordinatorConfig,
}

impl<'a> Coordinator<'a> {
    /// Create over a system.
    pub fn new(sys: &'a SnpSystem, cfg: CoordinatorConfig) -> Self {
        Coordinator { sys, matrix: build_matrix(sys), cfg }
    }

    /// The number of worker threads that will be used.
    pub fn effective_workers(&self) -> usize {
        crate::compute::pool::resolve_workers(self.cfg.workers)
    }

    /// Run from the initial configuration.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_from(ConfigVector::new(self.sys.initial_config()))
    }

    /// Run from a given configuration.
    pub fn run_from(&mut self, c0: ConfigVector) -> Result<RunReport> {
        let workers = self.effective_workers();
        // Build the backend pool: one independent instance per worker, so
        // the step phase can dispatch chunks concurrently.
        let mut pool: BackendPool = match &mut self.cfg.backend {
            BackendChoice::Host => {
                BackendPool::build(&HostBackendFactory::new(self.matrix.clone()), workers)?
            }
            BackendChoice::Xla { artifacts } => {
                let rt = crate::runtime::PjRt::cpu()?;
                let manifest = crate::runtime::Manifest::load(artifacts)?;
                BackendPool::build(
                    &XlaBackendFactory::new(rt, self.matrix.clone(), manifest),
                    workers,
                )?
            }
            BackendChoice::Custom(b) => {
                // take ownership; replace with Host to keep cfg valid —
                // a single instance cannot be replicated, so the pool has
                // one slot and the step phase runs serially over it
                let owned = std::mem::replace(b, Box::new(HostBackend::new(&self.matrix)));
                let name = owned.name().to_string();
                BackendPool::from_backends(name, vec![owned])
            }
        };
        if self.cfg.delta_cache > 0 {
            // one run-scoped S→S·M memo shared by every pooled backend
            pool.set_delta_cache(std::sync::Arc::new(DeltaCache::new(
                self.sys.num_rules(),
                self.sys.num_neurons(),
                self.cfg.delta_cache,
            )));
        }
        let trace = self.cfg.trace.as_deref();
        let run_span = trace.map(|t| t.begin(None));
        if let Some(t) = &self.cfg.trace {
            // run-private pool: checkout events land in this run's trace
            pool.set_trace(std::sync::Arc::clone(t));
        }
        let mut driver = worker::LevelDriver::new(
            self.sys,
            &self.matrix,
            workers,
            self.cfg.batch_target,
        )
        .with_spike_repr(self.cfg.spike_repr)
        .with_step_mode(self.cfg.step_mode);
        if let Some(t) = &self.cfg.trace {
            driver = driver.with_trace(std::sync::Arc::clone(t), run_span);
        }
        if let Some(token) = &self.cfg.cancel {
            driver = driver.with_cancel(token.clone());
        }
        let mut visited = match self.cfg.store_mode {
            StoreMode::Spill => VisitedStore::with_spill(
                self.sys.num_neurons(),
                self.cfg.max_configs.unwrap_or(4096).min(1 << 16),
                SpillShared::new(&self.cfg.spill),
            ),
            _ => VisitedStore::with_mode(
                self.cfg.store_mode,
                self.sys.num_neurons(),
                self.cfg.max_configs.unwrap_or(4096).min(1 << 16),
            ),
        };
        visited.try_intern(c0.as_slice())?;
        let mut level = vec![c0];
        let mut halting: Vec<ConfigVector> = Vec::new();
        let mut metrics = Metrics::default();
        let mut stop = StopReason::Exhausted;
        let mut depth = 0u32;
        // lint: allow(L2) — always-on run clock: feeds metrics.total_elapsed
        // in every report, not an optional timing
        let start = std::time::Instant::now();

        while !level.is_empty() {
            if let Some(token) = &self.cfg.cancel {
                if let Some(kind) = token.check() {
                    return Err(kind.into());
                }
            }
            if let Some(maxd) = self.cfg.max_depth {
                if depth >= maxd {
                    stop = StopReason::MaxDepth;
                    break;
                }
            }
            if let Some(maxc) = self.cfg.max_configs {
                if visited.len() >= maxc {
                    stop = StopReason::MaxConfigs;
                    break;
                }
            }
            let lvl = driver.process_level(
                &level,
                &pool,
                &mut visited,
                &mut halting,
                self.cfg.max_configs,
            )?;
            let truncated = lvl.truncated;
            metrics.record_level(depth, lvl.metrics);
            level = lvl.next_level;
            depth += 1;
            if truncated {
                stop = StopReason::MaxConfigs;
                break;
            }
        }
        if stop == StopReason::Exhausted
            && !halting.is_empty()
            && halting.iter().all(|c| c.is_zero())
        {
            stop = StopReason::ZeroConfig;
        }
        metrics.total_elapsed = start.elapsed();
        metrics.backend = pool.name().to_string();
        metrics.workers = workers;
        if let (Some(t), Some(s)) = (trace, run_span) {
            t.end(
                s,
                "run",
                &[("steps", metrics.total_steps()), ("configs", visited.len() as u64)],
            );
        }
        Ok(RunReport { visited, stop, halting, metrics })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ExploreOptions, Explorer};

    #[test]
    fn matches_single_threaded_explorer_on_paper_pi() {
        let sys = crate::generators::paper_pi();
        let mut coord = Coordinator::new(
            &sys,
            CoordinatorConfig { workers: 4, max_depth: Some(6), ..Default::default() },
        );
        let rep = coord.run().unwrap();
        let single =
            Explorer::new(&sys, ExploreOptions::breadth_first().max_depth(6)).run();
        assert_eq!(rep.visited.in_order(), single.visited.in_order());
        assert_eq!(rep.stop, StopReason::MaxDepth);
    }

    #[test]
    fn worker_count_does_not_change_result() {
        let sys = crate::generators::ring_with_branching(3, 2, 2);
        let mut orders = Vec::new();
        for w in [1, 2, 8] {
            let mut coord = Coordinator::new(
                &sys,
                CoordinatorConfig { workers: w, ..Default::default() },
            );
            let rep = coord.run().unwrap();
            orders.push(
                rep.visited.in_order().iter().map(|c| c.to_string()).collect::<Vec<_>>(),
            );
        }
        assert_eq!(orders[0], orders[1]);
        assert_eq!(orders[1], orders[2]);
    }

    #[test]
    fn finite_system_reports_zero_stop() {
        let sys = crate::generators::counter_chain(3, 2);
        let mut coord = Coordinator::new(&sys, CoordinatorConfig::default());
        let rep = coord.run().unwrap();
        assert_eq!(rep.stop, StopReason::ZeroConfig);
        assert!(rep.metrics.levels.len() > 2);
        assert_eq!(rep.metrics.backend, "host");
    }

    #[test]
    fn max_configs_budget() {
        let sys = crate::generators::paper_pi();
        let mut coord = Coordinator::new(
            &sys,
            CoordinatorConfig { max_configs: Some(20), ..Default::default() },
        );
        let rep = coord.run().unwrap();
        assert_eq!(rep.stop, StopReason::MaxConfigs);
        assert!(rep.visited.len() >= 20);
    }

    #[test]
    fn step_mode_does_not_change_coordinator_output() {
        let sys = crate::generators::ring_with_branching(3, 2, 2);
        let mut orders = Vec::new();
        for mode in
            [crate::compute::StepMode::Batch, crate::compute::StepMode::Delta, crate::compute::StepMode::Auto]
        {
            let mut coord = Coordinator::new(
                &sys,
                CoordinatorConfig { workers: 3, step_mode: mode, ..Default::default() },
            );
            let rep = coord.run().unwrap();
            orders.push(
                rep.visited.in_order().iter().map(|c| c.to_string()).collect::<Vec<_>>(),
            );
        }
        assert_eq!(orders[0], orders[1]);
        assert_eq!(orders[1], orders[2]);
    }

    #[test]
    fn store_mode_and_delta_cache_do_not_change_coordinator_output() {
        let sys = crate::generators::ring_with_branching(3, 2, 2);
        let mut orders = Vec::new();
        for (mode, cache) in [
            (StoreMode::Plain, DEFAULT_DELTA_CACHE),
            (StoreMode::Compressed, DEFAULT_DELTA_CACHE),
            (StoreMode::Compressed, 0),
            (StoreMode::Spill, DEFAULT_DELTA_CACHE),
        ] {
            let mut coord = Coordinator::new(
                &sys,
                CoordinatorConfig {
                    workers: 3,
                    store_mode: mode,
                    delta_cache: cache,
                    ..Default::default()
                },
            );
            let rep = coord.run().unwrap();
            assert_eq!(rep.visited.store_mode(), mode);
            orders.push(
                rep.visited.in_order().iter().map(|c| c.to_string()).collect::<Vec<_>>(),
            );
        }
        assert_eq!(orders[0], orders[1]);
        assert_eq!(orders[1], orders[2]);
        assert_eq!(orders[2], orders[3], "spill mode matches plain/compressed");
    }

    /// A resident budget of one byte forces every sealed segment to disk
    /// mid-run; the coordinator's output must not change, and the fault
    /// counters must show the eviction actually happened.
    #[test]
    fn spill_tiny_budget_is_byte_identical_and_faults() {
        let sys = crate::generators::paper_pi();
        let run = |store_mode, spill| {
            Coordinator::new(
                &sys,
                CoordinatorConfig {
                    workers: 3,
                    max_configs: Some(400),
                    store_mode,
                    spill,
                    ..Default::default()
                },
            )
            .run()
            .unwrap()
        };
        let plain = run(StoreMode::Plain, SpillConfig::default());
        let spilled = run(StoreMode::Spill, SpillConfig { dir: None, budget: 1 });
        assert_eq!(spilled.visited.in_order(), plain.visited.in_order());
        assert_eq!(spilled.stop, plain.stop);
        assert_eq!(spilled.halting, plain.halting);
        let sp = spilled.visited.spill_stats().expect("spill store reports stats");
        assert!(sp.spilled_bytes > 0, "tiny budget must evict: {sp:?}");
        assert!(sp.faults > 0, "intern probes must fault segments back in: {sp:?}");
    }

    #[test]
    fn cancel_token_turns_into_structured_errors() {
        use crate::util::CancelToken;
        let sys = crate::generators::paper_pi();
        let token = CancelToken::new();
        token.cancel();
        let err = Coordinator::new(
            &sys,
            CoordinatorConfig { cancel: Some(token), ..Default::default() },
        )
        .run()
        .expect_err("pre-cancelled run must fail");
        assert!(matches!(err, crate::Error::Cancelled(_)), "got: {err}");
        let expired = CancelToken::with_deadline(std::time::Duration::ZERO);
        let err = Coordinator::new(
            &sys,
            CoordinatorConfig { cancel: Some(expired), ..Default::default() },
        )
        .run()
        .expect_err("expired deadline must fail");
        assert!(matches!(err, crate::Error::DeadlineExceeded(_)), "got: {err}");
    }

    #[test]
    fn armed_quiet_token_does_not_change_coordinator_output() {
        use crate::util::CancelToken;
        let sys = crate::generators::ring_with_branching(3, 2, 2);
        let plain = Coordinator::new(
            &sys,
            CoordinatorConfig { workers: 3, ..Default::default() },
        )
        .run()
        .unwrap();
        let token = CancelToken::with_deadline(std::time::Duration::from_secs(3600));
        let armed = Coordinator::new(
            &sys,
            CoordinatorConfig { workers: 3, cancel: Some(token), ..Default::default() },
        )
        .run()
        .unwrap();
        assert_eq!(armed.visited.in_order(), plain.visited.in_order());
        assert_eq!(armed.stop, plain.stop);
        assert_eq!(armed.halting, plain.halting);
    }

    #[test]
    fn custom_backend_is_used() {
        struct Probe(std::sync::Arc<std::sync::atomic::AtomicU64>);
        impl StepBackend for Probe {
            fn name(&self) -> &str {
                "probe"
            }
            fn step_batch(&mut self, b: &crate::compute::StepBatch<'_>) -> Result<Vec<i64>> {
                self.0.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                // delegate to a throwaway host backend
                let m = crate::matrix::build_matrix(&crate::generators::paper_pi());
                HostBackend::new(&m).step_batch(b)
            }
        }
        let calls = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        let sys = crate::generators::paper_pi();
        let mut coord = Coordinator::new(
            &sys,
            CoordinatorConfig {
                max_depth: Some(3),
                backend: BackendChoice::Custom(Box::new(Probe(calls.clone()))),
                ..Default::default()
            },
        );
        let rep = coord.run().unwrap();
        assert!(calls.load(std::sync::atomic::Ordering::Relaxed) > 0);
        assert_eq!(rep.metrics.backend, "probe");
    }
}
