//! Level-synchronous parallel expansion (the coordinator's hot path).
//!
//! A level is processed in bounded **windows** so that frontier blow-ups
//! (Ψ can be exponential, paper §4.2) never materialize a whole level's
//! row set in memory: expand a window of parents in parallel → dispatch
//! its rows through the batcher → fold (dedup) → next window, with the
//! configuration budget checked between windows.

use std::sync::Arc;

use super::batcher::Batcher;
use crate::compute::{BackendPool, SpikeBuf, SpikeRepr, StepMode};
use crate::obs::{LevelMetrics, Span, Stopwatch, Trace};
use crate::engine::{applicable_rules_into, ApplicabilityMap, ConfigVector, SpikingEnumeration, VisitedStore};
use crate::error::Result;
use crate::matrix::TransitionMatrix;
use crate::snp::SnpSystem;

/// Output of one worker's expansion over its slice of the window:
/// flat `(C, S)` buffers plus halting configs, tagged with the parent's
/// window index for deterministic folding.
struct Expansion {
    configs: Vec<i64>,
    spikes: SpikeBuf,
    rows: usize,
    halting: Vec<(u32, ConfigVector)>,
    psi_total: u128,
}

/// Processes one BFS level: windowed parallel expand → batched step →
/// ordered fold.
pub struct LevelDriver<'a> {
    sys: &'a SnpSystem,
    #[allow(dead_code)]
    matrix: &'a TransitionMatrix,
    workers: usize,
    batch_target: usize,
    /// Concrete spiking-row representation (resolved from the requested
    /// [`SpikeRepr`] against the system's shape).
    use_sparse: bool,
    /// Requested stepping mode, resolved per dispatch against the pool's
    /// delta capability by the [`Batcher`].
    step_mode: StepMode,
    /// Parents expanded per window (bounds peak row memory together with
    /// the per-config Ψ).
    window_parents: usize,
    /// Optional span recorder: one `level` span per [`process_level`]
    /// call with `expand`/`step`/`fold` children. Phase durations feed
    /// the [`LevelMetrics`] table whether or not a trace is attached.
    trace: Option<Arc<Trace>>,
    /// Parent span for the `level` spans (the coordinator's `run` span).
    trace_parent: Option<Span>,
    /// Optional cancellation/deadline token, polled once per window —
    /// the same cadence as the configuration budget. `None` costs
    /// nothing.
    cancel: Option<crate::util::CancelToken>,
}

/// What a processed level yields.
pub struct LevelOutcome {
    /// Newly discovered configurations in deterministic order.
    pub next_level: Vec<ConfigVector>,
    /// True when the level was cut short by the configuration budget.
    pub truncated: bool,
    /// Counters and phase timings for this level — ready to hand to
    /// [`Metrics::record_level`](crate::obs::Metrics::record_level).
    pub metrics: LevelMetrics,
}

impl<'a> LevelDriver<'a> {
    /// Create a driver.
    pub fn new(
        sys: &'a SnpSystem,
        matrix: &'a TransitionMatrix,
        workers: usize,
        batch_target: usize,
    ) -> Self {
        LevelDriver {
            sys,
            matrix,
            workers: workers.max(1),
            batch_target: batch_target.max(1),
            use_sparse: SpikeRepr::Auto.use_sparse(sys.num_rules(), sys.num_neurons()),
            step_mode: StepMode::Auto,
            window_parents: 4096,
            trace: None,
            trace_parent: None,
            cancel: None,
        }
    }

    /// Attach a span recorder: each processed level records a `level`
    /// span (with `expand`/`step`/`fold` children) under `parent` —
    /// typically the coordinator's `run` span.
    pub fn with_trace(mut self, trace: Arc<Trace>, parent: Option<Span>) -> Self {
        self.trace = Some(trace);
        self.trace_parent = parent;
        self
    }

    /// Override the window size (testing / tuning).
    pub fn with_window(mut self, parents: usize) -> Self {
        self.window_parents = parents.max(1);
        self
    }

    /// Attach a cancellation/deadline token. [`process_level`] polls it
    /// once per window (beside the budget check) and returns a
    /// structured [`Error`](crate::Error) when it has fired — completed
    /// windows stay folded into `visited`, the rest are never expanded.
    ///
    /// [`process_level`]: LevelDriver::process_level
    pub fn with_cancel(mut self, token: crate::util::CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }

    /// Pick the spiking-row representation (default: auto).
    pub fn with_spike_repr(mut self, repr: SpikeRepr) -> Self {
        self.use_sparse = repr.use_sparse(self.sys.num_rules(), self.sys.num_neurons());
        self
    }

    /// Pick the stepping mode (default: auto — delta on delta-native
    /// pools). Level results are byte-identical in every mode.
    pub fn with_step_mode(mut self, mode: StepMode) -> Self {
        self.step_mode = mode;
        self
    }

    /// Concrete representation in use (`"dense"`/`"sparse"`).
    pub fn spike_repr_name(&self) -> &'static str {
        crate::compute::spike_repr_name(self.use_sparse)
    }

    /// Expand, evaluate and fold one level.
    ///
    /// The step phase draws from `pool`: each window's rows are chunked
    /// and evaluated concurrently on up to `workers` pooled backend
    /// instances (order-preserving, so results stay deterministic).
    ///
    /// `budget`: stop expanding further windows once the visited store
    /// holds at least this many configurations (resource bound, paper
    /// criterion 2 stays exact when `None`).
    pub fn process_level(
        &self,
        level: &[ConfigVector],
        pool: &BackendPool,
        visited: &mut VisitedStore,
        halting: &mut Vec<ConfigVector>,
        budget: Option<usize>,
    ) -> Result<LevelOutcome> {
        let n = self.sys.num_neurons();
        let r = self.sys.num_rules();
        let trace = self.trace.as_deref();
        let level_span = trace.map(|t| t.begin(self.trace_parent));
        let mut out = LevelOutcome {
            next_level: Vec::new(),
            truncated: false,
            metrics: LevelMetrics::default(),
        };

        for window in level.chunks(self.window_parents) {
            if let Some(token) = &self.cancel {
                if let Some(kind) = token.check() {
                    return Err(kind.into());
                }
            }
            if let Some(b) = budget {
                if visited.len() >= b {
                    out.truncated = true;
                    break;
                }
            }
            // --- expand (parallel over slices of the window) --------------
            let sw = Stopwatch::start(trace, level_span);
            let chunk = window.len().div_ceil(self.workers).max(1);
            let expansions: Vec<Expansion> = if self.workers == 1 || window.len() < 64 {
                vec![self.expand_slice(window, 0, r)]
            } else {
                std::thread::scope(|scope| {
                    let mut handles = Vec::new();
                    for (w, slice) in window.chunks(chunk).enumerate() {
                        let base = (w * chunk) as u32;
                        handles.push(scope.spawn(move || self.expand_slice(slice, base, r)));
                    }
                    handles
                        .into_iter()
                        // lint: allow(L1) — a panicking expand worker is a library bug;
                        // propagating the panic beats silently dropping its frontier slice
                        .map(|h| h.join().expect("expand worker panicked"))
                        .collect()
                })
            };
            out.metrics.expand_time +=
                sw.stop(trace, "expand", &[("parents", window.len() as u64)]);

            // --- step (batched across the backend pool) -------------------
            let sw = Stopwatch::start(trace, level_span);
            let total_rows: usize = expansions.iter().map(|e| e.rows).sum();
            let mut batcher =
                Batcher::with_repr(n, r, self.batch_target, total_rows, self.use_sparse)
                    .with_step_mode(self.step_mode);
            let mut halts: Vec<(u32, ConfigVector)> = Vec::new();
            for e in &expansions {
                out.metrics.psi_total += e.psi_total;
                batcher.push_rows(&e.configs, e.spikes.as_rows(), e.rows);
            }
            for e in expansions {
                halts.extend(e.halting);
            }
            let (results, steps, batches) = batcher.run_pool(pool, self.workers)?;
            out.metrics.steps += steps;
            out.metrics.batches += batches;
            out.metrics.step_time +=
                sw.stop(trace, "step", &[("rows", total_rows as u64)]);

            // --- fold (ordered dedup) --------------------------------------
            let sw = Stopwatch::start(trace, level_span);
            let rows_in = results.len() as u64;
            let new_before = out.next_level.len() as u64;
            halts.sort_by_key(|(i, _)| *i);
            halting.extend(halts.into_iter().map(|(_, c)| c));
            for child in results {
                // intern by slice: the admission check copies into the
                // arena only when new, and the already-owned child moves
                // into the next level without a clone (a spill fault-in
                // failure propagates as the level's Err)
                if visited.try_intern(child.as_slice())?.1 {
                    out.next_level.push(child);
                }
            }
            let new = out.next_level.len() as u64 - new_before;
            out.metrics.fold_time +=
                sw.stop(trace, "fold", &[("rows", rows_in), ("new", new)]);
        }
        out.metrics.new_configs = out.next_level.len() as u64;
        if let (Some(t), Some(s)) = (trace, level_span) {
            t.end(
                s,
                "level",
                &[
                    ("parents", level.len() as u64),
                    ("new", out.metrics.new_configs),
                    ("steps", out.metrics.steps),
                ],
            );
        }
        Ok(out)
    }

    fn expand_slice(&self, slice: &[ConfigVector], base: u32, r: usize) -> Expansion {
        let mut e = Expansion {
            configs: Vec::new(),
            spikes: SpikeBuf::with_repr(self.use_sparse, r),
            rows: 0,
            halting: Vec::new(),
            psi_total: 0,
        };
        let mut map = ApplicabilityMap::default();
        for (i, config) in slice.iter().enumerate() {
            let idx = base + i as u32;
            applicable_rules_into(self.sys, config.as_slice(), &mut map);
            if map.is_halting() {
                e.halting.push((idx, config.clone()));
                continue;
            }
            e.psi_total += map.psi();
            let mut en = SpikingEnumeration::new(&map, r);
            while en.fill_next_into(&mut e.spikes) {
                e.configs.extend(config.as_slice().iter().map(|&x| x as i64));
                e.rows += 1;
            }
        }
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::HostBackendFactory;
    use crate::matrix::build_matrix;

    fn pool(m: &TransitionMatrix, n: usize) -> BackendPool {
        BackendPool::build(&HostBackendFactory::new(m.clone()), n).unwrap()
    }

    #[test]
    fn single_level_matches_paper() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let driver = LevelDriver::new(&sys, &m, 2, 4);
        let backends = pool(&m, 2);
        let mut visited = VisitedStore::new();
        let c0 = ConfigVector::from(vec![2, 1, 1]);
        visited.insert(c0.clone());
        let mut halting = Vec::new();
        let out = driver
            .process_level(&[c0], &backends, &mut visited, &mut halting, None)
            .unwrap();
        let names: Vec<String> = out.next_level.iter().map(|c| c.to_string()).collect();
        assert_eq!(names, vec!["2-1-2", "1-1-2"]);
        assert_eq!(out.metrics.steps, 2);
        assert_eq!(out.metrics.psi_total, 2);
        assert_eq!(out.metrics.new_configs, 2);
        assert!(out.metrics.step_time >= std::time::Duration::ZERO);
        assert!(halting.is_empty());
        assert!(!out.truncated);
    }

    #[test]
    fn trace_records_level_phase_spans() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let trace = Arc::new(Trace::new());
        let driver = LevelDriver::new(&sys, &m, 2, 4).with_trace(Arc::clone(&trace), None);
        let backends = pool(&m, 2);
        let mut visited = VisitedStore::new();
        let c0 = ConfigVector::from(vec![2, 1, 1]);
        visited.insert(c0.clone());
        let mut halting = Vec::new();
        let traced = driver
            .process_level(&[c0.clone()], &backends, &mut visited, &mut halting, None)
            .unwrap();
        let recs = trace.records();
        let names: Vec<&str> = recs.iter().map(|r| r.name).collect();
        for phase in ["expand", "step", "fold", "level"] {
            assert!(names.contains(&phase), "{phase} span recorded");
        }
        // phase spans nest under the level span
        let level_id = recs.iter().find(|r| r.name == "level").unwrap().id;
        for r in recs.iter().filter(|r| ["expand", "step", "fold"].contains(&r.name)) {
            assert_eq!(r.parent, level_id);
        }
        // tracing never changes the level's output
        let bare = LevelDriver::new(&sys, &m, 2, 4);
        let mut visited2 = VisitedStore::new();
        visited2.insert(c0.clone());
        let mut halting2 = Vec::new();
        let plain = bare
            .process_level(&[c0], &backends, &mut visited2, &mut halting2, None)
            .unwrap();
        assert_eq!(
            traced.next_level.iter().map(|c| c.to_string()).collect::<Vec<_>>(),
            plain.next_level.iter().map(|c| c.to_string()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn halting_configs_collected_in_order() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let driver = LevelDriver::new(&sys, &m, 3, 4);
        let backends = pool(&m, 3);
        let mut visited = VisitedStore::new();
        let mut halting = Vec::new();
        let level = vec![
            ConfigVector::from(vec![1, 0, 0]),
            ConfigVector::from(vec![2, 1, 1]),
            ConfigVector::from(vec![0, 0, 0]),
        ];
        for c in &level {
            visited.insert(c.clone());
        }
        driver
            .process_level(&level, &backends, &mut visited, &mut halting, None)
            .unwrap();
        assert_eq!(
            halting.iter().map(|c| c.to_string()).collect::<Vec<_>>(),
            vec!["1-0-0", "0-0-0"]
        );
    }

    #[test]
    fn budget_truncates_between_windows() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let driver = LevelDriver::new(&sys, &m, 1, 4).with_window(1);
        let backends = pool(&m, 1);
        let mut visited = VisitedStore::new();
        let mut halting = Vec::new();
        // two-parent level with a budget that is already met
        let level = vec![
            ConfigVector::from(vec![2, 1, 1]),
            ConfigVector::from(vec![2, 1, 2]),
        ];
        for c in &level {
            visited.insert(c.clone());
        }
        let out = driver
            .process_level(&level, &backends, &mut visited, &mut halting, Some(2))
            .unwrap();
        assert!(out.truncated);
        assert!(out.next_level.is_empty());
    }

    #[test]
    fn fired_token_fails_the_level_with_a_structured_error() {
        use crate::util::CancelToken;
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let token = CancelToken::new();
        token.cancel();
        let driver = LevelDriver::new(&sys, &m, 1, 4).with_cancel(token);
        let backends = pool(&m, 1);
        let mut visited = VisitedStore::new();
        let c0 = ConfigVector::from(vec![2, 1, 1]);
        visited.insert(c0.clone());
        let mut halting = Vec::new();
        let err = driver
            .process_level(&[c0], &backends, &mut visited, &mut halting, None)
            .expect_err("cancelled level must fail");
        assert!(matches!(err, crate::Error::Cancelled(_)), "got: {err}");
        assert_eq!(visited.len(), 1, "no window was expanded");
    }

    #[test]
    fn spike_repr_does_not_change_level_results() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let mut results = Vec::new();
        for repr in [SpikeRepr::Dense, SpikeRepr::Sparse] {
            let driver = LevelDriver::new(&sys, &m, 2, 4).with_spike_repr(repr);
            let backends = pool(&m, 2);
            let mut visited = VisitedStore::new();
            let c0 = ConfigVector::from(vec![2, 1, 1]);
            visited.insert(c0.clone());
            let mut halting = Vec::new();
            let out = driver
                .process_level(&[c0], &backends, &mut visited, &mut halting, None)
                .unwrap();
            results.push(out.next_level.iter().map(|c| c.to_string()).collect::<Vec<_>>());
        }
        assert_eq!(results[0], results[1]);
        // and auto resolves dense on the tiny paper system
        let auto = LevelDriver::new(&sys, &m, 2, 4);
        assert_eq!(auto.spike_repr_name(), "dense");
        assert_eq!(
            LevelDriver::new(&sys, &m, 2, 4).with_spike_repr(SpikeRepr::Sparse).spike_repr_name(),
            "sparse"
        );
    }

    #[test]
    fn step_mode_does_not_change_level_results() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let mut results = Vec::new();
        for mode in [StepMode::Batch, StepMode::Delta, StepMode::Auto] {
            let driver = LevelDriver::new(&sys, &m, 2, 4).with_step_mode(mode);
            let backends = pool(&m, 2);
            let mut visited = VisitedStore::new();
            let c0 = ConfigVector::from(vec![2, 1, 1]);
            visited.insert(c0.clone());
            let mut halting = Vec::new();
            let out = driver
                .process_level(&[c0], &backends, &mut visited, &mut halting, None)
                .unwrap();
            results.push(out.next_level.iter().map(|c| c.to_string()).collect::<Vec<_>>());
        }
        assert_eq!(results[0], results[1]);
        assert_eq!(results[1], results[2]);
        assert_eq!(results[0], vec!["2-1-2", "1-1-2"]);
    }

    #[test]
    fn window_size_does_not_change_results() {
        let sys = crate::generators::ring_with_branching(3, 2, 2);
        let m = build_matrix(&sys);
        let mut runs = Vec::new();
        for window in [1usize, 2, 1024] {
            let driver = LevelDriver::new(&sys, &m, 2, 8).with_window(window);
            let backends = pool(&m, 2);
            let mut visited = VisitedStore::new();
            let c0 = ConfigVector::new(sys.initial_config());
            visited.insert(c0.clone());
            let mut halting = Vec::new();
            let mut level = vec![c0];
            while !level.is_empty() {
                let out = driver
                    .process_level(&level, &backends, &mut visited, &mut halting, None)
                    .unwrap();
                level = out.next_level;
            }
            runs.push(
                visited.in_order().iter().map(|c| c.to_string()).collect::<Vec<_>>(),
            );
        }
        assert_eq!(runs[0], runs[1]);
        assert_eq!(runs[1], runs[2]);
    }
}
