//! Level-synchronous parallel expansion (the coordinator's hot path).
//!
//! A level is processed in bounded **windows** so that frontier blow-ups
//! (Ψ can be exponential, paper §4.2) never materialize a whole level's
//! row set in memory: expand a window of parents in parallel → dispatch
//! its rows through the batcher → fold (dedup) → next window, with the
//! configuration budget checked between windows.

use std::time::Instant;

use super::batcher::Batcher;
use super::metrics::LevelMetrics;
use crate::compute::{BackendPool, SpikeBuf, SpikeRepr, StepMode};
use crate::engine::{applicable_rules_into, ApplicabilityMap, ConfigVector, SpikingEnumeration, VisitedStore};
use crate::error::Result;
use crate::matrix::TransitionMatrix;
use crate::snp::SnpSystem;

/// Output of one worker's expansion over its slice of the window:
/// flat `(C, S)` buffers plus halting configs, tagged with the parent's
/// window index for deterministic folding.
struct Expansion {
    configs: Vec<i64>,
    spikes: SpikeBuf,
    rows: usize,
    halting: Vec<(u32, ConfigVector)>,
    psi_total: u128,
}

/// Processes one BFS level: windowed parallel expand → batched step →
/// ordered fold.
pub struct LevelDriver<'a> {
    sys: &'a SnpSystem,
    #[allow(dead_code)]
    matrix: &'a TransitionMatrix,
    workers: usize,
    batch_target: usize,
    /// Concrete spiking-row representation (resolved from the requested
    /// [`SpikeRepr`] against the system's shape).
    use_sparse: bool,
    /// Requested stepping mode, resolved per dispatch against the pool's
    /// delta capability by the [`Batcher`].
    step_mode: StepMode,
    /// Parents expanded per window (bounds peak row memory together with
    /// the per-config Ψ).
    window_parents: usize,
}

/// What a processed level yields.
pub struct LevelOutcome {
    /// Newly discovered configurations in deterministic order.
    pub next_level: Vec<ConfigVector>,
    /// Rows evaluated.
    pub steps: u64,
    /// Backend dispatches.
    pub batches: u64,
    /// Σ Ψ of the level.
    pub psi_total: u128,
    /// True when the level was cut short by the configuration budget.
    pub truncated: bool,
    /// Time in the expand phase.
    pub expand_time: std::time::Duration,
    /// Time in the step phase.
    pub step_time: std::time::Duration,
    /// Time in the fold phase.
    pub fold_time: std::time::Duration,
}

impl<'a> LevelDriver<'a> {
    /// Create a driver.
    pub fn new(
        sys: &'a SnpSystem,
        matrix: &'a TransitionMatrix,
        workers: usize,
        batch_target: usize,
    ) -> Self {
        LevelDriver {
            sys,
            matrix,
            workers: workers.max(1),
            batch_target: batch_target.max(1),
            use_sparse: SpikeRepr::Auto.use_sparse(sys.num_rules(), sys.num_neurons()),
            step_mode: StepMode::Auto,
            window_parents: 4096,
        }
    }

    /// Override the window size (testing / tuning).
    pub fn with_window(mut self, parents: usize) -> Self {
        self.window_parents = parents.max(1);
        self
    }

    /// Pick the spiking-row representation (default: auto).
    pub fn with_spike_repr(mut self, repr: SpikeRepr) -> Self {
        self.use_sparse = repr.use_sparse(self.sys.num_rules(), self.sys.num_neurons());
        self
    }

    /// Pick the stepping mode (default: auto — delta on delta-native
    /// pools). Level results are byte-identical in every mode.
    pub fn with_step_mode(mut self, mode: StepMode) -> Self {
        self.step_mode = mode;
        self
    }

    /// Concrete representation in use (`"dense"`/`"sparse"`).
    pub fn spike_repr_name(&self) -> &'static str {
        crate::compute::spike_repr_name(self.use_sparse)
    }

    /// Expand, evaluate and fold one level.
    ///
    /// The step phase draws from `pool`: each window's rows are chunked
    /// and evaluated concurrently on up to `workers` pooled backend
    /// instances (order-preserving, so results stay deterministic).
    ///
    /// `budget`: stop expanding further windows once the visited store
    /// holds at least this many configurations (resource bound, paper
    /// criterion 2 stays exact when `None`).
    pub fn process_level(
        &self,
        level: &[ConfigVector],
        pool: &BackendPool,
        visited: &mut VisitedStore,
        halting: &mut Vec<ConfigVector>,
        budget: Option<usize>,
    ) -> Result<LevelOutcome> {
        let n = self.sys.num_neurons();
        let r = self.sys.num_rules();
        let mut out = LevelOutcome {
            next_level: Vec::new(),
            steps: 0,
            batches: 0,
            psi_total: 0,
            truncated: false,
            expand_time: Default::default(),
            step_time: Default::default(),
            fold_time: Default::default(),
        };

        for window in level.chunks(self.window_parents) {
            if let Some(b) = budget {
                if visited.len() >= b {
                    out.truncated = true;
                    break;
                }
            }
            // --- expand (parallel over slices of the window) --------------
            let t0 = Instant::now();
            let chunk = window.len().div_ceil(self.workers).max(1);
            let expansions: Vec<Expansion> = if self.workers == 1 || window.len() < 64 {
                vec![self.expand_slice(window, 0, r)]
            } else {
                std::thread::scope(|scope| {
                    let mut handles = Vec::new();
                    for (w, slice) in window.chunks(chunk).enumerate() {
                        let base = (w * chunk) as u32;
                        handles.push(scope.spawn(move || self.expand_slice(slice, base, r)));
                    }
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("expand worker panicked"))
                        .collect()
                })
            };
            out.expand_time += t0.elapsed();

            // --- step (batched across the backend pool) -------------------
            let t1 = Instant::now();
            let total_rows: usize = expansions.iter().map(|e| e.rows).sum();
            let mut batcher =
                Batcher::with_repr(n, r, self.batch_target, total_rows, self.use_sparse)
                    .with_step_mode(self.step_mode);
            let mut halts: Vec<(u32, ConfigVector)> = Vec::new();
            for e in &expansions {
                out.psi_total += e.psi_total;
                batcher.push_rows(&e.configs, e.spikes.as_rows(), e.rows);
            }
            for e in expansions {
                halts.extend(e.halting);
            }
            let (results, steps, batches) = batcher.run_pool(pool, self.workers)?;
            out.steps += steps;
            out.batches += batches;
            out.step_time += t1.elapsed();

            // --- fold (ordered dedup) --------------------------------------
            let t2 = Instant::now();
            halts.sort_by_key(|(i, _)| *i);
            halting.extend(halts.into_iter().map(|(_, c)| c));
            for child in results {
                // intern by slice: the admission check copies into the
                // arena only when new, and the already-owned child moves
                // into the next level without a clone
                if visited.intern(child.as_slice()).1 {
                    out.next_level.push(child);
                }
            }
            out.fold_time += t2.elapsed();
        }
        Ok(out)
    }

    fn expand_slice(&self, slice: &[ConfigVector], base: u32, r: usize) -> Expansion {
        let mut e = Expansion {
            configs: Vec::new(),
            spikes: SpikeBuf::with_repr(self.use_sparse, r),
            rows: 0,
            halting: Vec::new(),
            psi_total: 0,
        };
        let mut map = ApplicabilityMap::default();
        for (i, config) in slice.iter().enumerate() {
            let idx = base + i as u32;
            applicable_rules_into(self.sys, config.as_slice(), &mut map);
            if map.is_halting() {
                e.halting.push((idx, config.clone()));
                continue;
            }
            e.psi_total += map.psi();
            let mut en = SpikingEnumeration::new(&map, r);
            while en.fill_next_into(&mut e.spikes) {
                e.configs.extend(config.as_slice().iter().map(|&x| x as i64));
                e.rows += 1;
            }
        }
        e
    }
}

impl From<&LevelOutcome> for LevelMetrics {
    fn from(o: &LevelOutcome) -> LevelMetrics {
        LevelMetrics {
            new_configs: o.next_level.len() as u64,
            steps: o.steps,
            batches: o.batches,
            psi_total: o.psi_total,
            expand_time: o.expand_time,
            step_time: o.step_time,
            fold_time: o.fold_time,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::HostBackendFactory;
    use crate::matrix::build_matrix;

    fn pool(m: &TransitionMatrix, n: usize) -> BackendPool {
        BackendPool::build(&HostBackendFactory::new(m.clone()), n).unwrap()
    }

    #[test]
    fn single_level_matches_paper() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let driver = LevelDriver::new(&sys, &m, 2, 4);
        let backends = pool(&m, 2);
        let mut visited = VisitedStore::new();
        let c0 = ConfigVector::from(vec![2, 1, 1]);
        visited.insert(c0.clone());
        let mut halting = Vec::new();
        let out = driver
            .process_level(&[c0], &backends, &mut visited, &mut halting, None)
            .unwrap();
        let names: Vec<String> = out.next_level.iter().map(|c| c.to_string()).collect();
        assert_eq!(names, vec!["2-1-2", "1-1-2"]);
        assert_eq!(out.steps, 2);
        assert_eq!(out.psi_total, 2);
        assert!(halting.is_empty());
        assert!(!out.truncated);
    }

    #[test]
    fn halting_configs_collected_in_order() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let driver = LevelDriver::new(&sys, &m, 3, 4);
        let backends = pool(&m, 3);
        let mut visited = VisitedStore::new();
        let mut halting = Vec::new();
        let level = vec![
            ConfigVector::from(vec![1, 0, 0]),
            ConfigVector::from(vec![2, 1, 1]),
            ConfigVector::from(vec![0, 0, 0]),
        ];
        for c in &level {
            visited.insert(c.clone());
        }
        driver
            .process_level(&level, &backends, &mut visited, &mut halting, None)
            .unwrap();
        assert_eq!(
            halting.iter().map(|c| c.to_string()).collect::<Vec<_>>(),
            vec!["1-0-0", "0-0-0"]
        );
    }

    #[test]
    fn budget_truncates_between_windows() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let driver = LevelDriver::new(&sys, &m, 1, 4).with_window(1);
        let backends = pool(&m, 1);
        let mut visited = VisitedStore::new();
        let mut halting = Vec::new();
        // two-parent level with a budget that is already met
        let level = vec![
            ConfigVector::from(vec![2, 1, 1]),
            ConfigVector::from(vec![2, 1, 2]),
        ];
        for c in &level {
            visited.insert(c.clone());
        }
        let out = driver
            .process_level(&level, &backends, &mut visited, &mut halting, Some(2))
            .unwrap();
        assert!(out.truncated);
        assert!(out.next_level.is_empty());
    }

    #[test]
    fn spike_repr_does_not_change_level_results() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let mut results = Vec::new();
        for repr in [SpikeRepr::Dense, SpikeRepr::Sparse] {
            let driver = LevelDriver::new(&sys, &m, 2, 4).with_spike_repr(repr);
            let backends = pool(&m, 2);
            let mut visited = VisitedStore::new();
            let c0 = ConfigVector::from(vec![2, 1, 1]);
            visited.insert(c0.clone());
            let mut halting = Vec::new();
            let out = driver
                .process_level(&[c0], &backends, &mut visited, &mut halting, None)
                .unwrap();
            results.push(out.next_level.iter().map(|c| c.to_string()).collect::<Vec<_>>());
        }
        assert_eq!(results[0], results[1]);
        // and auto resolves dense on the tiny paper system
        let auto = LevelDriver::new(&sys, &m, 2, 4);
        assert_eq!(auto.spike_repr_name(), "dense");
        assert_eq!(
            LevelDriver::new(&sys, &m, 2, 4).with_spike_repr(SpikeRepr::Sparse).spike_repr_name(),
            "sparse"
        );
    }

    #[test]
    fn step_mode_does_not_change_level_results() {
        let sys = crate::generators::paper_pi();
        let m = build_matrix(&sys);
        let mut results = Vec::new();
        for mode in [StepMode::Batch, StepMode::Delta, StepMode::Auto] {
            let driver = LevelDriver::new(&sys, &m, 2, 4).with_step_mode(mode);
            let backends = pool(&m, 2);
            let mut visited = VisitedStore::new();
            let c0 = ConfigVector::from(vec![2, 1, 1]);
            visited.insert(c0.clone());
            let mut halting = Vec::new();
            let out = driver
                .process_level(&[c0], &backends, &mut visited, &mut halting, None)
                .unwrap();
            results.push(out.next_level.iter().map(|c| c.to_string()).collect::<Vec<_>>());
        }
        assert_eq!(results[0], results[1]);
        assert_eq!(results[1], results[2]);
        assert_eq!(results[0], vec!["2-1-2", "1-1-2"]);
    }

    #[test]
    fn window_size_does_not_change_results() {
        let sys = crate::generators::ring_with_branching(3, 2, 2);
        let m = build_matrix(&sys);
        let mut runs = Vec::new();
        for window in [1usize, 2, 1024] {
            let driver = LevelDriver::new(&sys, &m, 2, 8).with_window(window);
            let backends = pool(&m, 2);
            let mut visited = VisitedStore::new();
            let c0 = ConfigVector::new(sys.initial_config());
            visited.insert(c0.clone());
            let mut halting = Vec::new();
            let mut level = vec![c0];
            while !level.is_empty() {
                let out = driver
                    .process_level(&level, &backends, &mut visited, &mut halting, None)
                    .unwrap();
                level = out.next_level;
            }
            runs.push(
                visited.in_order().iter().map(|c| c.to_string()).collect::<Vec<_>>(),
            );
        }
        assert_eq!(runs[0], runs[1]);
        assert_eq!(runs[1], runs[2]);
    }
}
