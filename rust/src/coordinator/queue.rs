//! Level queue with spill accounting — bounds frontier memory and reports
//! high-water marks (large systems can have millions of configs per
//! level; the coordinator needs to know when it is the memory bottleneck).

use crate::engine::ConfigVector;

/// FIFO of BFS levels with peak-size tracking.
#[derive(Debug, Default)]
pub struct LevelQueue {
    current: Vec<ConfigVector>,
    peak_level: usize,
    total_enqueued: u64,
}

impl LevelQueue {
    /// Empty queue.
    pub fn new() -> Self {
        LevelQueue::default()
    }

    /// Install the next level.
    pub fn replace(&mut self, level: Vec<ConfigVector>) {
        self.peak_level = self.peak_level.max(level.len());
        self.total_enqueued += level.len() as u64;
        self.current = level;
    }

    /// Borrow the current level.
    pub fn current(&self) -> &[ConfigVector] {
        &self.current
    }

    /// Is the frontier empty?
    pub fn is_empty(&self) -> bool {
        self.current.is_empty()
    }

    /// Largest level seen.
    pub fn peak_level(&self) -> usize {
        self.peak_level
    }

    /// Total configurations ever enqueued.
    pub fn total_enqueued(&self) -> u64 {
        self.total_enqueued
    }

    /// Approximate bytes held by the current level.
    pub fn approx_bytes(&self) -> usize {
        self.current
            .iter()
            .map(|c| c.len() * std::mem::size_of::<u64>() + std::mem::size_of::<ConfigVector>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(v: &[u64]) -> ConfigVector {
        ConfigVector::from(v.to_vec())
    }

    #[test]
    fn tracks_peak_and_total() {
        let mut q = LevelQueue::new();
        q.replace(vec![c(&[1]), c(&[2])]);
        q.replace(vec![c(&[3]), c(&[4]), c(&[5])]);
        q.replace(vec![c(&[6])]);
        assert_eq!(q.peak_level(), 3);
        assert_eq!(q.total_enqueued(), 6);
        assert!(!q.is_empty());
        assert_eq!(q.current().len(), 1);
        assert!(q.approx_bytes() > 0);
    }
}
