//! Run metrics: per-level phase timings and aggregate throughput.

use std::time::Duration;

/// Metrics for one BFS level.
#[derive(Debug, Clone, Default)]
pub struct LevelMetrics {
    /// Newly discovered configurations.
    pub new_configs: u64,
    /// `(C, S)` rows evaluated.
    pub steps: u64,
    /// Backend dispatches.
    pub batches: u64,
    /// Σ Ψ across expanded configs.
    pub psi_total: u128,
    /// Expand-phase wall time.
    pub expand_time: Duration,
    /// Step-phase wall time.
    pub step_time: Duration,
    /// Fold-phase wall time.
    pub fold_time: Duration,
}

/// Aggregate metrics for a run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Per-level records (index = depth).
    pub levels: Vec<LevelMetrics>,
    /// Total wall time.
    pub total_elapsed: Duration,
    /// Backend name.
    pub backend: String,
    /// Worker threads used.
    pub workers: usize,
}

impl Metrics {
    /// Record one completed level.
    pub fn record_level(&mut self, depth: u32, outcome: &super::worker::LevelOutcome) {
        debug_assert_eq!(depth as usize, self.levels.len());
        self.levels.push(LevelMetrics::from(outcome));
    }

    /// Total rows evaluated.
    pub fn total_steps(&self) -> u64 {
        self.levels.iter().map(|l| l.steps).sum()
    }

    /// Total backend dispatches.
    pub fn total_batches(&self) -> u64 {
        self.levels.iter().map(|l| l.batches).sum()
    }

    /// Total configurations discovered (excluding the root).
    pub fn total_new_configs(&self) -> u64 {
        self.levels.iter().map(|l| l.new_configs).sum()
    }

    /// Steps per second over the whole run.
    pub fn steps_per_sec(&self) -> f64 {
        let secs = self.total_elapsed.as_secs_f64();
        if secs > 0.0 {
            self.total_steps() as f64 / secs
        } else {
            0.0
        }
    }

    /// Render a per-level phase table.
    pub fn render_table(&self) -> String {
        let mut t = crate::util::fmt::Table::new(&[
            "depth", "new", "steps", "batches", "expand", "step", "fold",
        ]);
        for (d, l) in self.levels.iter().enumerate() {
            t.row(&[
                d.to_string(),
                l.new_configs.to_string(),
                l.steps.to_string(),
                l.batches.to_string(),
                crate::util::fmt::human_ns(l.expand_time.as_nanos() as f64),
                crate::util::fmt::human_ns(l.step_time.as_nanos() as f64),
                crate::util::fmt::human_ns(l.fold_time.as_nanos() as f64),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut m = Metrics::default();
        m.levels.push(LevelMetrics { new_configs: 2, steps: 2, batches: 1, ..Default::default() });
        m.levels.push(LevelMetrics { new_configs: 4, steps: 6, batches: 2, ..Default::default() });
        assert_eq!(m.total_steps(), 8);
        assert_eq!(m.total_batches(), 3);
        assert_eq!(m.total_new_configs(), 6);
        m.total_elapsed = Duration::from_secs(2);
        assert!((m.steps_per_sec() - 4.0).abs() < 1e-9);
        let table = m.render_table();
        assert!(table.contains("depth"));
        assert_eq!(table.lines().count(), 4);
    }
}
