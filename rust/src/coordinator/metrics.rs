//! Run metrics — a view over the unified [`crate::obs`] registry types.
//!
//! The per-level table and aggregate throughput figures used to be
//! coordinator-private; they now live in [`crate::obs::metrics`] so the
//! explorer paths (serial and pipelined, via `--timings`/`--trace`) fill
//! the identical structure. This module re-exports the types under their
//! historical paths (`coordinator::{LevelMetrics, Metrics}`) — existing
//! callers compile unchanged.

pub use crate::obs::{LevelMetrics, Metrics};
