//! E8 — property suite: matrix semantics ≡ direct semantics, across guard
//! kinds, seeds printed for replay.

use snapse::baseline::DirectSimulator;
use snapse::engine::{applicable_rules, ConfigVector, ExploreOptions, Explorer};
use snapse::generators::{random_system, RandomSystemParams};
use snapse::snp::{Rule, SystemBuilder};

#[test]
fn property_reachable_sets_agree_on_200_random_systems() {
    let params = RandomSystemParams::default();
    for seed in 0..200u64 {
        let sys = random_system(&params, seed);
        let sim = DirectSimulator::new(&sys);
        let (direct, complete) = sim.reachable(300);
        let mut opts = ExploreOptions::breadth_first();
        if !complete {
            opts = opts.max_configs(300);
        }
        let rep = Explorer::new(&sys, opts).run();
        let engine_order = rep.visited.in_order();
        if complete {
            let a: std::collections::BTreeSet<_> = direct.iter().collect();
            let b: std::collections::BTreeSet<_> = engine_order.iter().collect();
            assert_eq!(a, b, "seed {seed}");
        } else {
            for (i, (x, y)) in direct.iter().zip(engine_order.iter()).enumerate().take(150) {
                assert_eq!(x, y, "seed {seed} diverges at BFS position {i}");
            }
        }
    }
}

#[test]
fn property_psi_equals_choice_product() {
    let params = RandomSystemParams::default();
    for seed in 200..280u64 {
        let sys = random_system(&params, seed);
        let sim = DirectSimulator::new(&sys);
        let c0 = ConfigVector::new(sys.initial_config());
        let map = applicable_rules(&sys, &c0);
        let choices = sim.choices(&c0);
        if map.is_halting() {
            assert!(choices.is_empty(), "seed {seed}");
        } else {
            assert_eq!(choices.len() as u128, map.psi(), "seed {seed}");
        }
    }
}

#[test]
fn property_spike_conservation_invariant() {
    // for systems whose every rule has produced·out_degree == consumed,
    // total spikes are invariant along every reachable configuration
    for m in [3usize, 5, 8] {
        let sys = snapse::generators::ring(m, 2);
        let total: u64 = sys.initial_config().iter().sum();
        let rep = Explorer::new(&sys, ExploreOptions::breadth_first().max_configs(500)).run();
        for c in rep.visited.in_order() {
            assert_eq!(c.total_spikes(), total, "ring({m}) config {c}");
        }
    }
}

#[test]
fn property_monotone_drain_invariant() {
    // forgetting-free systems with consumed ≥ produced·out_degree never
    // gain spikes
    let sys = snapse::generators::counter_chain(5, 4);
    let rep = Explorer::new(&sys, ExploreOptions::breadth_first()).run();
    let start: u64 = sys.initial_config().iter().sum();
    for c in rep.visited.in_order() {
        assert!(c.total_spikes() <= start);
    }
}

#[test]
fn exact_guard_blocks_above_threshold() {
    // a^2 exact: 3 spikes must NOT fire (vs threshold semantics)
    let exact = SystemBuilder::new("exact")
        .neuron(3, vec![Rule::exact(2, 1)])
        .neuron(0, vec![])
        .synapse(0, 1)
        .build()
        .unwrap();
    let map = applicable_rules(&exact, &ConfigVector::from(vec![3, 0]));
    assert!(map.is_halting());

    let thresh = SystemBuilder::new("thresh")
        .neuron(3, vec![Rule::b3(2)])
        .neuron(0, vec![])
        .synapse(0, 1)
        .build()
        .unwrap();
    let map = applicable_rules(&thresh, &ConfigVector::from(vec![3, 0]));
    assert_eq!(map.psi(), 1);
}

#[test]
fn regex_guard_system_full_reachability() {
    // even_gen (regex guards) explored by both engines
    let sys = snapse::generators::even_generator();
    let sim = DirectSimulator::new(&sys);
    let (direct, complete) = sim.reachable(100);
    assert!(complete);
    let rep = Explorer::new(&sys, ExploreOptions::breadth_first()).run();
    let engine_order = rep.visited.in_order();
    let a: std::collections::BTreeSet<_> = direct.iter().collect();
    let b: std::collections::BTreeSet<_> = engine_order.iter().collect();
    assert_eq!(a, b);
}

#[test]
fn forgetting_rules_consume_without_producing() {
    let sys = SystemBuilder::new("forget")
        .neuron(2, vec![Rule::forget(2)])
        .neuron(0, vec![])
        .synapse(0, 1)
        .build()
        .unwrap();
    let rep = Explorer::new(&sys, ExploreOptions::breadth_first()).run();
    let names: Vec<String> = rep.visited.in_order().iter().map(|c| c.to_string()).collect();
    assert_eq!(names, vec!["2-0", "0-0"]);
    assert_eq!(rep.stop, snapse::engine::StopReason::ZeroConfig);
}

#[test]
fn mixed_guard_neuron_nondeterminism() {
    // one neuron with exact(1), threshold(1): at k=1 both fire → Ψ=2;
    // at k=2 only the threshold rule fires → Ψ=1
    let sys = SystemBuilder::new("mixed")
        .neuron(1, vec![Rule::exact(1, 1), Rule::b3(1)])
        .neuron(0, vec![])
        .synapse(0, 1)
        .build()
        .unwrap();
    let m1 = applicable_rules(&sys, &ConfigVector::from(vec![1, 0]));
    assert_eq!(m1.psi(), 2);
    let m2 = applicable_rules(&sys, &ConfigVector::from(vec![2, 0]));
    assert_eq!(m2.psi(), 1);
    assert_eq!(m2.neuron(0), &[1]);
}
