//! E3 — integration test: the ℕ∖{1} generation claim.

use snapse::engine::{generated_set, RandomWalk};

#[test]
fn nat_generator_generates_exactly_n_minus_one() {
    let sys = snapse::generators::nat_generator();
    let set = generated_set(&sys, 30);
    let expect: std::collections::BTreeSet<u64> = (2..=30).collect();
    assert_eq!(set, expect);
}

#[test]
fn one_is_never_generated() {
    let sys = snapse::generators::nat_generator();
    assert!(!generated_set(&sys, 50).contains(&1));
}

#[test]
fn random_walks_only_realize_members_of_the_generated_set() {
    // soundness: every first-gap observed on any random path must be in
    // the exact generated set
    let sys = snapse::generators::nat_generator();
    let set = generated_set(&sys, 60);
    for seed in 0..80 {
        let rec = RandomWalk::new(&sys, seed).run(80);
        if let Some(g) = rec.trace.generated() {
            assert!(set.contains(&g), "seed {seed} realized non-member {g}");
        }
    }
}

#[test]
fn random_walks_cover_small_members() {
    // completeness (statistical): small members show up within 300 seeds
    let sys = snapse::generators::nat_generator();
    let mut seen = std::collections::BTreeSet::new();
    for seed in 0..300 {
        if let Some(g) = RandomWalk::new(&sys, seed).run(40).trace.generated() {
            seen.insert(g);
        }
    }
    for n in 2..=4u64 {
        assert!(seen.contains(&n), "gap {n} never realized in 300 walks: {seen:?}");
    }
}

#[test]
fn paper_pi_b3_recast_degenerates_to_gap_one() {
    // The all-spiking (b-3) Π fires σ3 every step it holds spikes: the
    // only achievable first-gap is 1. Documented in EXPERIMENTS.md E3.
    let sys = snapse::generators::paper_pi();
    let set = generated_set(&sys, 15);
    assert_eq!(set.into_iter().collect::<Vec<_>>(), vec![1]);
}

#[test]
fn divisibility_verdicts_match_arithmetic() {
    use snapse::engine::{ExploreOptions, Explorer};
    for n in [6u64, 9, 10, 14, 15, 21, 22] {
        for d in [2u64, 3, 7] {
            let sys = snapse::generators::divisibility_checker(n, d);
            let rep = Explorer::new(&sys, ExploreOptions::breadth_first()).run();
            assert_eq!(
                snapse::generators::divisible_verdict(&rep),
                n % d == 0,
                "{d} | {n}"
            );
        }
    }
}
