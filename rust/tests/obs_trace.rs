//! Integration tests for the observability layer (`snapse::obs`).
//!
//! Two contracts are pinned here:
//! - the JSONL trace export follows its documented schema — every line
//!   is valid JSON, phase names come from the fixed vocabulary, spans
//!   nest, and the trailing `meta` line summarizes the ring;
//! - tracing and timings change **no report byte** — the paper log and
//!   the JSON report are identical with and without them, on the serial
//!   and the pipelined engine alike.

use std::collections::HashMap;
use std::sync::Arc;

use snapse::engine::{ExploreOptions, Explorer};
use snapse::obs::{Trace, PHASE_NAMES};
use snapse::util::JsonValue as J;

fn trace_text(trace: &Trace) -> String {
    let mut buf = Vec::new();
    trace.write_jsonl(&mut buf).expect("in-memory write cannot fail");
    String::from_utf8(buf).expect("JSONL is UTF-8")
}

#[test]
fn trace_jsonl_is_wellformed_and_uses_the_pinned_vocabulary() {
    let sys = snapse::generators::paper_pi();
    let trace = Arc::new(Trace::new());
    let _report = Explorer::new(
        &sys,
        ExploreOptions::breadth_first().max_depth(8).trace(Arc::clone(&trace)),
    )
    .run();

    let text = trace_text(&trace);
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() >= 3, "expected spans + meta, got:\n{text}");

    // every line is valid JSON with the documented keys; the last line
    // is the meta summary
    let mut records: HashMap<u64, (u64, u64, u64)> = HashMap::new(); // id → (parent, start, end)
    for (i, line) in lines.iter().enumerate() {
        let v = J::parse(line).unwrap_or_else(|e| panic!("line {i} `{line}`: {e}"));
        let ty = v.get("type").and_then(|t| t.as_str()).expect("every line has `type`");
        if i == lines.len() - 1 {
            assert_eq!(ty, "meta", "last line is the meta summary: {line}");
            assert_eq!(
                v.get("records").and_then(|r| r.as_usize()),
                Some(lines.len() - 1),
                "meta record count matches the body"
            );
            assert_eq!(v.get("dropped").and_then(|d| d.as_u64()), Some(0));
            continue;
        }
        assert!(ty == "span" || ty == "event", "{line}");
        let name = v.get("name").and_then(|n| n.as_str()).expect("every record has `name`");
        assert!(PHASE_NAMES.contains(&name), "`{name}` is not in the pinned vocabulary");
        assert!(v.get("fields").is_some(), "every record has `fields`: {line}");
        let id = v.get("id").and_then(|x| x.as_u64()).expect("id");
        let parent = v.get("parent").and_then(|x| x.as_u64()).expect("parent");
        let start = v.get("start_us").and_then(|x| x.as_u64()).expect("start_us");
        let dur = v.get("dur_us").and_then(|x| x.as_u64()).expect("dur_us");
        assert!(records.insert(id, (parent, start, start + dur)).is_none(), "dup id {id}");
    }

    // spans nest: every non-root parent exists and the child's
    // [start, end] window lies within the parent's
    for (&id, &(parent, start, end)) in &records {
        if parent == 0 {
            continue;
        }
        let &(_, pstart, pend) = records
            .get(&parent)
            .unwrap_or_else(|| panic!("record {id} references missing parent {parent}"));
        assert!(start >= pstart, "record {id} starts before its parent");
        assert!(end <= pend, "record {id} outlives its parent");
    }

    // the serial engine emits the root run span and the per-batch phases
    for needle in ["\"name\":\"run\"", "\"name\":\"enumerate\"", "\"name\":\"step\"", "\"name\":\"fold\""]
    {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
}

#[test]
fn serial_reports_are_byte_identical_with_tracing_and_timings_on() {
    let sys = snapse::generators::paper_pi();
    let plain = Explorer::new(&sys, ExploreOptions::breadth_first().max_depth(8)).run();
    let trace = Arc::new(Trace::new());
    let traced = Explorer::new(
        &sys,
        ExploreOptions::breadth_first()
            .max_depth(8)
            .trace(Arc::clone(&trace))
            .timings(true),
    )
    .run();

    assert_eq!(
        snapse::output::render_paper_log(&sys, &plain),
        snapse::output::render_paper_log(&sys, &traced),
        "paper log must be byte-identical with tracing on"
    );
    assert_eq!(
        plain.to_json("paper_pi").to_string_compact(),
        traced.to_json("paper_pi").to_string_compact(),
        "JSON report must be byte-identical with tracing on"
    );
    assert!(!trace.is_empty(), "the traced run recorded spans");
    assert!(plain.stats.levels.is_empty(), "untimed runs book no level table");
    let steps: u64 = traced.stats.levels.iter().map(|l| l.steps).sum();
    assert_eq!(steps, traced.stats.steps, "level table accounts for every step");
    let new: u64 = traced.stats.levels.iter().map(|l| l.new_configs).sum();
    assert_eq!(
        new + 1, // the initial configuration is interned before level 0
        traced.visited.len() as u64,
        "level table accounts for every discovered configuration"
    );
}

#[test]
fn pipelined_reports_are_byte_identical_with_tracing_and_timings_on() {
    let sys = snapse::generators::paper_pi();
    let plain =
        Explorer::new(&sys, ExploreOptions::breadth_first().max_depth(7).workers(4)).run();
    let trace = Arc::new(Trace::new());
    let traced = Explorer::new(
        &sys,
        ExploreOptions::breadth_first()
            .max_depth(7)
            .workers(4)
            .trace(Arc::clone(&trace))
            .timings(true),
    )
    .run();

    assert_eq!(
        plain.to_json("paper_pi").to_string_compact(),
        traced.to_json("paper_pi").to_string_compact(),
        "pipelined JSON report must be byte-identical with tracing on"
    );
    assert_eq!(
        snapse::output::render_paper_log(&sys, &plain),
        snapse::output::render_paper_log(&sys, &traced),
        "pipelined paper log must be byte-identical with tracing on"
    );
    // the parallel engine emits worker wait/step spans alongside the run
    let text = trace_text(&trace);
    assert!(text.contains("\"name\":\"run\""), "{text}");
    assert!(text.contains("\"name\":\"step\""), "{text}");
    let steps: u64 = traced.stats.levels.iter().map(|l| l.steps).sum();
    assert_eq!(steps, traced.stats.steps, "level table accounts for every step");
}
