//! Parser integration: every format round-trips and all formats agree.

use snapse::engine::{ExploreOptions, Explorer};
use snapse::generators::{random_system, RandomSystemParams};
use snapse::parser::{parse_paper_files, parse_snpl, system_from_json, system_to_json};

#[test]
fn json_roundtrip_on_100_random_systems() {
    let params = RandomSystemParams::default();
    for seed in 0..100 {
        let sys = random_system(&params, seed);
        let text = system_to_json(&sys).to_string_compact();
        let again = system_from_json(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(sys.neurons, again.neurons, "seed {seed}");
        assert_eq!(sys.synapses, again.synapses, "seed {seed}");
        assert_eq!(sys.input, again.input, "seed {seed}");
        assert_eq!(sys.output, again.output, "seed {seed}");
    }
}

#[test]
fn snpl_roundtrip_on_random_systems() {
    let params = RandomSystemParams::default();
    for seed in 0..60 {
        let sys = random_system(&params, seed);
        let text = snapse::parser::snpl::to_snpl(&sys);
        let again = parse_snpl(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(sys.neurons, again.neurons, "seed {seed}");
        assert_eq!(sys.synapses, again.synapses, "seed {seed}");
    }
}

#[test]
fn three_formats_explore_identically() {
    // the same system through builder / paper files / snpl must produce
    // identical computation trees
    let from_builder = snapse::generators::paper_pi();
    let from_files =
        parse_paper_files("2 1 1", "-1 1 1 -2 1 1 1 -1 1 0 0 -1 0 0 -2", "2 2 $ 1 $ 1 2")
            .unwrap()
            .to_system("pi")
            .unwrap();
    let from_json =
        system_from_json(&system_to_json(&from_builder).to_string_compact()).unwrap();
    let explore = |sys: &snapse::snp::SnpSystem| {
        Explorer::new(sys, ExploreOptions::breadth_first().max_depth(7))
            .run()
            .visited
            .in_order()
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
    };
    let a = explore(&from_builder);
    assert_eq!(a, explore(&from_files));
    assert_eq!(a, explore(&from_json));
}

#[test]
fn paper_file_loading_from_disk() {
    let dir = std::env::temp_dir().join("snapse_paperfmt_test");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("confVec"), "2 1 1").unwrap();
    std::fs::write(dir.join("M"), "-1 1 1 -2 1 1 1 -1 1 0 0 -1 0 0 -2").unwrap();
    std::fs::write(dir.join("r"), "2 2 $ 1 $ 1 2").unwrap();
    let input = snapse::parser::paperfmt::load_paper_files(
        &dir.join("confVec"),
        &dir.join("M"),
        &dir.join("r"),
    )
    .unwrap();
    assert_eq!(input.config.as_slice(), &[2, 1, 1]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shipped_snpl_files_parse_and_match_generators() {
    // the files under examples/systems/ must stay in sync with the
    // programmatic generators
    let pi_text = std::fs::read_to_string("examples/systems/paper_pi.snpl").unwrap();
    let pi = parse_snpl(&pi_text).unwrap();
    assert_eq!(
        snapse::matrix::build_matrix(&pi).as_row_major(),
        snapse::matrix::build_matrix(&snapse::generators::paper_pi()).as_row_major()
    );
    let nat_text = std::fs::read_to_string("examples/systems/nat_gen.snpl").unwrap();
    let nat = parse_snpl(&nat_text).unwrap();
    let reference = snapse::generators::nat_generator();
    // labels differ (ascii vs σ); compare structure
    for (a, b) in nat.neurons.iter().zip(reference.neurons.iter()) {
        assert_eq!(a.initial_spikes, b.initial_spikes);
        assert_eq!(a.rules, b.rules);
    }

    // the paper-format triplet reconstructs Π as well
    let input = snapse::parser::paperfmt::load_paper_files(
        std::path::Path::new("examples/systems/paper_confVec"),
        std::path::Path::new("examples/systems/paper_M"),
        std::path::Path::new("examples/systems/paper_r"),
    )
    .unwrap();
    let sys = input.to_system("pi").unwrap();
    assert_eq!(
        snapse::matrix::build_matrix(&sys).as_row_major(),
        snapse::matrix::build_matrix(&pi).as_row_major()
    );
}

#[test]
fn cli_loads_snpl_files() {
    let dir = std::env::temp_dir().join("snapse_cli_load_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pi.snpl");
    let sys = snapse::generators::paper_pi();
    std::fs::write(&path, snapse::parser::snpl::to_snpl(&sys)).unwrap();
    let loaded = snapse::cli::load_system(path.to_str().unwrap()).unwrap();
    assert_eq!(loaded.num_rules(), 5);
    std::fs::remove_dir_all(&dir).ok();
}
