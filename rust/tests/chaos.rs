//! Chaos suite: deterministic fault injection (`compute::faulty`) across
//! the engine matrix {error, panic, latency} × {serial, 4 workers} ×
//! {paper_pi, rule_heavy:6:12:2}, plus the daemon's shed/deadline wire
//! contract.
//!
//! The contracts under test:
//! - a **single** injected fault in the pipelined engine is survived by
//!   quarantine-and-retry and the report stays **byte-identical** to a
//!   fault-free run (the paper's reproducibility contract holds under
//!   failure);
//! - an **unretryable** fault (serial path, or a fault window that also
//!   kills the retry) fails in bounded time with a structured error that
//!   names the injected fault — never a hang, never an abort;
//! - injected **latency** is only slow, never wrong: byte-identical
//!   output on both engine paths;
//! - over the wire, a saturated daemon sheds with 503/`overloaded` and
//!   an expired deadline answers 504/`deadline_exceeded` — structured
//!   bodies, daemon keeps serving.

use std::sync::Arc;
use std::time::{Duration, Instant};

use snapse::compute::{BackendFactory, FaultPlan, FaultyBackendFactory, HostBackendFactory};
use snapse::engine::{ExploreOptions, Explorer, StopReason};
use snapse::matrix::build_matrix;
use snapse::snp::SnpSystem;

/// Nothing in this suite is allowed to run away: every failure mode must
/// resolve (Ok or structured Err) well inside this bound.
const BOUNDED: Duration = Duration::from_secs(60);

fn systems() -> Vec<SnpSystem> {
    vec![
        snapse::generators::paper_pi(),
        snapse::generators::from_spec("rule_heavy:6:12:2")
            .expect("spec grammar")
            .expect("builtin spec"),
    ]
}

/// Bounded exploration: deep enough that faults at call 2 always fire,
/// bounded enough that the whole matrix stays fast.
fn opts(workers: usize) -> ExploreOptions {
    ExploreOptions::breadth_first().max_depth(7).max_configs(4000).workers(workers)
}

fn faulty(sys: &SnpSystem, plan: FaultPlan) -> Arc<FaultyBackendFactory> {
    let host: Arc<dyn BackendFactory> = Arc::new(HostBackendFactory::new(build_matrix(sys)));
    Arc::new(FaultyBackendFactory::new(host, plan))
}

/// Fault-free reference bytes at the given worker count.
fn clean_json(sys: &SnpSystem, workers: usize) -> String {
    Explorer::new(sys, opts(workers)).run().to_json(&sys.name).to_string_compact()
}

#[test]
fn retried_parallel_faults_keep_reports_byte_identical() {
    for sys in systems() {
        let reference = clean_json(&sys, 4);
        for plan in [
            FaultPlan::error_at(2),
            FaultPlan::panic_at(2),
            FaultPlan::latency_at(2, 40),
        ] {
            let start = Instant::now();
            let label = format!("{plan:?} on {}", sys.name);
            let factory = faulty(&sys, plan);
            let report = Explorer::with_factory(&sys, opts(4), Arc::clone(&factory))
                .try_run()
                .unwrap_or_else(|e| panic!("{label}: single fault must be survived: {e}"));
            assert!(factory.injected() >= 1, "{label}: the fault never fired");
            assert_eq!(
                report.to_json(&sys.name).to_string_compact(),
                reference,
                "{label}: retried run must be byte-identical to fault-free"
            );
            assert!(start.elapsed() < BOUNDED, "{label}: took {:?}", start.elapsed());
        }
    }
}

#[test]
fn serial_latency_is_slow_but_never_wrong() {
    for sys in systems() {
        let reference = clean_json(&sys, 1);
        let factory = faulty(&sys, FaultPlan::latency_at(2, 40));
        let report = Explorer::with_factory(&sys, opts(1), Arc::clone(&factory))
            .try_run()
            .expect("latency is not a failure");
        assert!(factory.injected() >= 1, "{}: the sleep never fired", sys.name);
        assert_eq!(report.to_json(&sys.name).to_string_compact(), reference);
    }
}

#[test]
fn serial_faults_fail_with_structured_errors_in_bounded_time() {
    // the serial reference path has no retry machinery by design: one
    // backend instance, one structured error, partial work discarded
    for sys in systems() {
        for (plan, needle) in [
            (FaultPlan::error_at(2), "injected fault"),
            (FaultPlan::panic_at(2), "injected panic"),
        ] {
            let start = Instant::now();
            let label = format!("{plan:?} on {}", sys.name);
            let err = Explorer::with_factory(&sys, opts(1), faulty(&sys, plan))
                .try_run()
                .expect_err("serial faults are unretryable and must surface");
            let msg = err.to_string();
            assert!(msg.contains(needle), "{label}: error names the fault: {msg}");
            assert!(start.elapsed() < BOUNDED, "{label}: took {:?}", start.elapsed());
        }
    }
}

#[test]
fn faults_that_outlive_the_retry_fail_cleanly_in_parallel() {
    for sys in systems() {
        // every call from 2 on faults: the quarantine retry is guaranteed
        // to hit the window too, whatever the concurrent interleaving
        let start = Instant::now();
        let err = Explorer::with_factory(
            &sys,
            opts(4),
            faulty(&sys, FaultPlan::error_at(2).repeated(u64::MAX / 2)),
        )
        .try_run()
        .expect_err("fault + failed retry must fail the run");
        let msg = err.to_string();
        assert!(msg.contains("injected fault"), "{}: {msg}", sys.name);
        assert!(msg.contains("retry after"), "{}: both attempts named: {msg}", sys.name);
        assert!(start.elapsed() < BOUNDED, "{}: took {:?}", sys.name, start.elapsed());
    }
}

#[test]
fn fired_tokens_stop_both_engine_paths_as_stop_reasons() {
    let sys = snapse::generators::paper_pi();
    for workers in [1usize, 4] {
        let token = snapse::util::CancelToken::new();
        token.cancel();
        let report = Explorer::new(&sys, opts(workers).cancel(token)).run();
        assert_eq!(report.stop, StopReason::Cancelled, "workers={workers}");

        let expired = snapse::util::CancelToken::with_deadline(Duration::from_millis(0));
        let report = Explorer::new(&sys, opts(workers).cancel(expired)).run();
        assert_eq!(report.stop, StopReason::DeadlineExceeded, "workers={workers}");
    }
}

/// Over-the-wire shed + deadline contract (the in-process twin of the CI
/// smoke probes): 503/`overloaded` when slots are saturated,
/// 504/`deadline_exceeded` when the budget expires, structured bodies
/// both ways, and the daemon keeps serving afterwards.
#[test]
fn daemon_sheds_and_times_out_with_structured_bodies() {
    use snapse::serve::{client, ServeConfig, Server};

    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        explore_slots: 0, // every compute sheds — the saturated extreme
        ..ServeConfig::default()
    })
    .expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr").to_string();
    let handle = std::thread::spawn(move || server.run());

    let (status, body) =
        client::post(&addr, "/v1/run", r#"{"system":"paper_pi","depth":5}"#).unwrap();
    assert_eq!(status, 503, "{body}");
    let parsed = snapse::util::JsonValue::parse(&body).expect("structured shed body");
    assert_eq!(
        parsed.get("error").and_then(|e| e.get("code")).and_then(|c| c.as_str()),
        Some("overloaded"),
        "{body}"
    );

    // health degrades with a reason instead of lying
    let (status, health) = client::get(&addr, "/healthz").unwrap();
    assert_eq!(status, 200);
    assert!(health.contains("slots"), "degraded reason names the slots: {health}");

    let (status, _) = client::post(&addr, "/v1/shutdown", "").unwrap();
    assert_eq!(status, 200);
    handle.join().expect("server thread").expect("clean exit");

    // deadline: a fresh daemon with free slots, an impossible budget
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        ..ServeConfig::default()
    })
    .expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr").to_string();
    let handle = std::thread::spawn(move || server.run());

    let start = Instant::now();
    let (status, body) = client::post(
        &addr,
        "/v1/run",
        r#"{"system":"wide_ring:16:4:3","configs":200000,"deadline_ms":1}"#,
    )
    .unwrap();
    assert_eq!(status, 504, "{body}");
    let parsed = snapse::util::JsonValue::parse(&body).expect("structured deadline body");
    assert_eq!(
        parsed.get("error").and_then(|e| e.get("code")).and_then(|c| c.as_str()),
        Some("deadline_exceeded"),
        "{body}"
    );
    assert!(
        start.elapsed() < Duration::from_secs(2) + Duration::from_millis(1),
        "deadline must bound the wait: {:?}",
        start.elapsed()
    );

    // and the same query without a deadline still completes fine
    let (status, body) =
        client::post(&addr, "/v1/run", r#"{"system":"paper_pi","depth":4}"#).unwrap();
    assert_eq!(status, 200, "daemon serves on after a 504: {body}");

    let (status, _) = client::post(&addr, "/v1/shutdown", "").unwrap();
    assert_eq!(status, 200);
    handle.join().expect("server thread").expect("clean exit");
}
