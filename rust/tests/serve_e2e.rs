//! End-to-end tests for the serve daemon: real `TcpListener`, real
//! concurrent clients over the wire, one process.
//!
//! The acceptance contract:
//! - two identical queries return byte-identical report JSON, the second
//!   marked `"cache":"hit"`;
//! - N concurrent cold requests for one system cause exactly one
//!   exploration (the single-flight `computations` counter);
//! - malformed requests get structured JSON errors and the daemon keeps
//!   serving.

use std::sync::Arc;

use snapse::serve::{client, router::ServeState, ServeConfig, Server};

/// Boot a daemon on an ephemeral loopback port. Returns the address, the
/// shared state (for counter assertions), and the join handle.
fn boot(
    explore_workers: usize,
) -> (String, Arc<ServeState>, std::thread::JoinHandle<snapse::Result<()>>) {
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        explore_workers,
        handler_threads: 8,
        cache_capacity: 64,
        ..ServeConfig::default()
    })
    .expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr").to_string();
    let state = server.state();
    let handle = std::thread::spawn(move || server.run());
    (addr, state, handle)
}

fn shutdown(addr: &str, handle: std::thread::JoinHandle<snapse::Result<()>>) {
    let (status, _) = client::post(addr, "/v1/shutdown", "").expect("shutdown request");
    assert_eq!(status, 200);
    handle.join().expect("server thread").expect("clean exit");
}

/// Extract everything from the `"hash"` key onward — the part of the
/// envelope that must be byte-identical between a miss and a hit.
fn hash_and_report(body: &str) -> &str {
    let at = body.find("\"hash\"").expect("envelope has a hash field");
    &body[at..]
}

fn cache_marker(body: &str) -> &str {
    for marker in ["miss", "hit", "coalesced"] {
        if body.starts_with(&format!("{{\"cache\":\"{marker}\"")) {
            return marker;
        }
    }
    panic!("no cache marker in {body}");
}

#[test]
fn identical_queries_are_byte_identical_and_cached() {
    let (addr, state, handle) = boot(1);
    let body = r#"{"system":"paper_pi","depth":6}"#;

    let (s1, r1) = client::post(&addr, "/v1/run", body).unwrap();
    assert_eq!(s1, 200, "{r1}");
    assert_eq!(cache_marker(&r1), "miss");
    assert!(r1.contains("\"all_gen_ck\""), "{r1}");

    let (s2, r2) = client::post(&addr, "/v1/run", body).unwrap();
    assert_eq!(s2, 200);
    assert_eq!(cache_marker(&r2), "hit", "second identical query must hit: {r2}");
    assert_eq!(
        hash_and_report(&r1),
        hash_and_report(&r2),
        "hit must return the exact bytes of the original report"
    );

    assert_eq!(
        state.cache.stats.computations.load(std::sync::atomic::Ordering::Relaxed),
        1,
        "one exploration for two identical queries"
    );
    shutdown(&addr, handle);
}

#[test]
fn concurrent_cold_requests_single_flight() {
    let (addr, state, handle) = boot(1);
    // a workload slow enough that the cold window is wide: every client
    // fires before the first exploration finishes
    let body = r#"{"system":"wide_ring:16:4:3","configs":4000}"#;
    const CLIENTS: usize = 8;

    let mut bodies: Vec<String> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..CLIENTS {
            let addr = addr.clone();
            handles.push(scope.spawn(move || {
                let (status, body) = client::post(&addr, "/v1/run", body).unwrap();
                assert_eq!(status, 200, "{body}");
                body
            }));
        }
        for h in handles {
            bodies.push(h.join().unwrap());
        }
    });

    assert_eq!(
        state.cache.stats.computations.load(std::sync::atomic::Ordering::Relaxed),
        1,
        "N concurrent cold requests must trigger exactly one exploration"
    );
    let reference = hash_and_report(&bodies[0]);
    for b in &bodies {
        assert_eq!(hash_and_report(b), reference, "all clients share one report");
    }
    let misses = bodies.iter().filter(|b| cache_marker(b) == "miss").count();
    assert_eq!(misses, 1, "exactly one client reports the miss");
    shutdown(&addr, handle);
}

#[test]
fn source_forms_share_one_cache_entry() {
    let (addr, _state, handle) = boot(1);
    let (s1, r1) =
        client::post(&addr, "/v1/run", r#"{"system":"paper_pi","depth":5}"#).unwrap();
    assert_eq!(s1, 200, "{r1}");
    assert_eq!(cache_marker(&r1), "miss");

    // the same system as an inline JSON document → same content hash
    let sys_json = snapse::parser::system_to_json(&snapse::generators::paper_pi())
        .to_string_compact();
    let body = format!(r#"{{"system":{sys_json},"format":"json","depth":5}}"#);
    let (s2, r2) = client::post(&addr, "/v1/run", &body).unwrap();
    assert_eq!(s2, 200, "{r2}");
    assert_eq!(cache_marker(&r2), "hit", "JSON form must hit the spec form's entry: {r2}");
    assert_eq!(hash_and_report(&r1), hash_and_report(&r2));

    // …and as inline .snpl text
    let snpl = snapse::parser::snpl::to_snpl(&snapse::generators::paper_pi());
    let body = snapse::util::JsonValue::obj([
        ("system", snapse::util::JsonValue::str(snpl)),
        ("format", snapse::util::JsonValue::str("snpl")),
        ("depth", snapse::util::JsonValue::num(5.0)),
    ]);
    let (s3, r3) = client::post(&addr, "/v1/run", &body.to_string_compact()).unwrap();
    assert_eq!(s3, 200, "{r3}");
    assert_eq!(cache_marker(&r3), "hit", ".snpl form must hit the same entry: {r3}");
    shutdown(&addr, handle);
}

#[test]
fn malformed_requests_get_structured_errors_and_daemon_survives() {
    let (addr, _state, handle) = boot(1);
    let cases: &[(&str, &str, &str)] = &[
        ("POST", "/v1/run", "this is not json"),
        ("POST", "/v1/run", "[1,2,3]"),
        ("POST", "/v1/run", "{}"),
        ("POST", "/v1/run", r#"{"system":"not_a_builtin"}"#),
        ("POST", "/v1/run", r#"{"system":"paper_pi","mode":"zigzag"}"#),
        ("POST", "/v1/run", r#"{"system":"neuron {","format":"snpl"}"#),
        ("POST", "/v1/generated", r#"{"system":"ring:4:2"}"#),
        ("POST", "/v1/does_not_exist", "{}"),
        ("GET", "/v1/run", ""),
    ];
    for (method, path, body) in cases {
        let (status, resp) = client::request(&addr, method, path, Some(body)).unwrap();
        assert!(
            (400..=405).contains(&status),
            "{method} {path} `{body}` → {status}: {resp}"
        );
        let parsed = snapse::util::JsonValue::parse(&resp)
            .unwrap_or_else(|e| panic!("{method} {path}: unstructured error `{resp}`: {e}"));
        assert!(parsed.get("error").is_some(), "{resp}");
        assert!(
            parsed.get("error").unwrap().get("message").is_some(),
            "error carries a message: {resp}"
        );
    }
    // raw garbage on the socket — not even HTTP
    {
        use std::io::{Read, Write};
        let mut stream = std::net::TcpStream::connect(&addr).unwrap();
        stream.write_all(b"\x00\x01\x02 total nonsense\r\n\r\n").unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).ok();
        assert!(out.contains("400"), "garbage gets a 400, not a hangup: {out}");
    }
    // the daemon still serves real queries afterwards
    let (status, body) =
        client::post(&addr, "/v1/run", r#"{"system":"paper_pi","depth":4}"#).unwrap();
    assert_eq!(status, 200, "daemon must survive malformed traffic: {body}");
    shutdown(&addr, handle);
}

#[test]
fn all_endpoints_roundtrip_and_report_consistent_results() {
    let (addr, _state, handle) = boot(2);
    // health + stats
    let (status, body) = client::get(&addr, "/healthz").unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("\"status\":\"ok\""));
    let (status, body) = client::get(&addr, "/v1/stats").unwrap();
    assert_eq!(status, 200, "{body}");

    // run: the served allGenCk must match a local reference exploration
    let (status, body) =
        client::post(&addr, "/v1/run", r#"{"system":"paper_pi","depth":3}"#).unwrap();
    assert_eq!(status, 200, "{body}");
    let local = {
        use snapse::engine::{ExploreOptions, Explorer};
        let sys = snapse::generators::paper_pi();
        let rep = Explorer::new(&sys, ExploreOptions::breadth_first().max_depth(3)).run();
        rep.to_json("paper_pi").to_string_compact()
    };
    let served = snapse::util::JsonValue::parse(&body).unwrap();
    assert_eq!(
        served.get("report").unwrap().to_string_compact(),
        local,
        "served report equals the local reference exploration"
    );

    // generated: nat_gen produces ℕ∖{1} up to the bound
    let (status, body) =
        client::post(&addr, "/v1/generated", r#"{"system":"nat_gen","max":8}"#).unwrap();
    assert_eq!(status, 200, "{body}");
    let parsed = snapse::util::JsonValue::parse(&body).unwrap();
    let generated = parsed.get("report").unwrap().get("generated").unwrap();
    let nums: Vec<u64> =
        generated.as_arr().unwrap().iter().map(|v| v.as_u64().unwrap()).collect();
    assert_eq!(nums, vec![2, 3, 4, 5, 6, 7, 8]);

    // analyze: counter chain is deterministic + confluent
    let (status, body) =
        client::post(&addr, "/v1/analyze", r#"{"system":"counter:4:3"}"#).unwrap();
    assert_eq!(status, 200, "{body}");
    let parsed = snapse::util::JsonValue::parse(&body).unwrap();
    let analysis = parsed.get("report").unwrap().get("analysis").unwrap();
    assert_eq!(analysis.get("deterministic").unwrap().as_bool(), Some(true));
    assert_eq!(analysis.get("confluent").unwrap().as_bool(), Some(true));

    // info: paper_pi's 5×3 matrix
    let (status, body) =
        client::post(&addr, "/v1/info", r#"{"system":"paper_pi"}"#).unwrap();
    assert_eq!(status, 200, "{body}");
    let parsed = snapse::util::JsonValue::parse(&body).unwrap();
    let matrix = parsed.get("report").unwrap().get("matrix").unwrap();
    assert_eq!(matrix.get("rows").unwrap().as_usize(), Some(5));
    assert_eq!(matrix.get("cols").unwrap().as_usize(), Some(3));

    // stats reflect the traffic
    let (_, body) = client::get(&addr, "/v1/stats").unwrap();
    let parsed = snapse::util::JsonValue::parse(&body).unwrap();
    let cache = parsed.get("cache").unwrap();
    assert_eq!(cache.get("computations").unwrap().as_usize(), Some(4));
    assert!(parsed.get("requests").unwrap().as_usize().unwrap() >= 6);
    shutdown(&addr, handle);
}

#[test]
fn metrics_endpoint_speaks_prometheus_over_the_wire() {
    let (addr, _state, handle) = boot(1);
    // traffic first, so the cache and delta-cache families have samples
    let (s, b) = client::post(&addr, "/v1/run", r#"{"system":"paper_pi","depth":4}"#).unwrap();
    assert_eq!(s, 200, "{b}");
    client::post(&addr, "/v1/run", r#"{"system":"paper_pi","depth":4}"#).unwrap();

    // raw exchange to inspect the headers: /metrics is text, not JSON
    let raw = {
        use std::io::{Read, Write};
        let mut stream = std::net::TcpStream::connect(&addr).unwrap();
        stream.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        out
    };
    assert!(raw.starts_with("HTTP/1.1 200 OK\r\n"), "{raw}");
    assert!(raw.contains("content-type: text/plain; version=0.0.4\r\n"), "{raw}");
    assert!(!raw.contains("application/json"), "{raw}");
    let body1 = raw.split("\r\n\r\n").nth(1).expect("response body").to_string();

    // the whole body parses as text exposition: `# TYPE fam kind`
    // comments and `name[{labels}] value` samples with numeric values
    let mut families = 0;
    for line in body1.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let kind = rest.split(' ').nth(1).expect(line);
            assert!(matches!(kind, "counter" | "gauge" | "histogram"), "{line}");
            families += 1;
            continue;
        }
        let (name, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("bad sample `{line}`"));
        assert!(!name.is_empty(), "{line}");
        assert!(value.parse::<f64>().is_ok(), "non-numeric sample `{line}`");
    }
    assert!(families >= 5, "expected several metric families:\n{body1}");
    for needle in [
        "snapse_request_seconds_bucket{le=\"+Inf\"}",
        "snapse_report_cache_hits_total 1",
        "snapse_delta_cache_entries{system=\"",
        "snapse_requests_total",
        "snapse_uptime_seconds",
    ] {
        assert!(body1.contains(needle), "missing `{needle}`:\n{body1}");
    }

    // counters are monotone across scrapes
    let (s, body2) = client::get(&addr, "/metrics").unwrap();
    assert_eq!(s, 200);
    let sample = |body: &str, prefix: &str| -> f64 {
        body.lines()
            .find(|l| l.starts_with(prefix))
            .and_then(|l| l.rsplit_once(' '))
            .map(|(_, v)| v.parse::<f64>().unwrap())
            .unwrap_or_else(|| panic!("no `{prefix}` sample in {body}"))
    };
    assert!(
        sample(&body2, "snapse_requests_total") > sample(&body1, "snapse_requests_total"),
        "request counter must be monotone:\n{body1}\n{body2}"
    );
    assert!(
        sample(&body2, "snapse_request_seconds_count")
            > sample(&body1, "snapse_request_seconds_count"),
        "latency histogram count must be monotone"
    );
    shutdown(&addr, handle);
}

#[test]
fn distinct_parameters_do_not_cross_contaminate() {
    let (addr, state, handle) = boot(1);
    let (_, r1) = client::post(&addr, "/v1/run", r#"{"system":"paper_pi","depth":3}"#).unwrap();
    let (_, r2) = client::post(&addr, "/v1/run", r#"{"system":"paper_pi","depth":4}"#).unwrap();
    assert_eq!(cache_marker(&r1), "miss");
    assert_eq!(cache_marker(&r2), "miss", "different depth = different entry");
    assert_ne!(hash_and_report(&r1), hash_and_report(&r2), "reports differ by depth");
    let (_, r3) = client::post(
        &addr,
        "/v1/run",
        r#"{"system":"paper_pi","depth":3,"mode":"dfs"}"#,
    )
    .unwrap();
    assert_eq!(cache_marker(&r3), "miss", "different mode = different entry");
    assert_eq!(
        state.cache.stats.computations.load(std::sync::atomic::Ordering::Relaxed),
        3
    );
    shutdown(&addr, handle);
}
