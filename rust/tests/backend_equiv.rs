//! Backend equivalence: the XLA/PJRT device path must agree bit-for-bit
//! with the pure-Rust host path on every workload. Requires
//! `make artifacts` (tests are skipped with a notice when absent, so
//! `cargo test` stays green on a fresh checkout).

use snapse::compute::{HostBackend, StepBackend, StepBatch};
use snapse::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use snapse::matrix::build_matrix;
use snapse::runtime::{Manifest, PjRt};
use snapse::util::Rng;

fn manifest() -> Option<Manifest> {
    Manifest::load(std::path::Path::new("artifacts")).ok()
}

macro_rules! require_artifacts {
    () => {
        match manifest() {
            Some(m) => m,
            None => {
                eprintln!("SKIP: no artifacts/ (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn non_binary_spiking_buffers_rejected_by_every_backend() {
    // StepBatch::validate enforces {0,1} spiking entries; the host backend
    // (and through the shared validation, the device backend) must reject
    // a buffer with a stray 2 instead of silently computing 2·M rows.
    let sys = snapse::generators::paper_pi();
    let m = build_matrix(&sys);
    let mut host = HostBackend::new(&m);
    let configs = vec![2i64, 1, 1];
    let good = vec![1u8, 0, 1, 1, 0];
    assert!(host
        .step_batch(&StepBatch { b: 1, n: 3, r: 5, configs: &configs, spikes: &good })
        .is_ok());
    let bad = vec![1u8, 0, 2, 1, 0];
    let err = host
        .step_batch(&StepBatch { b: 1, n: 3, r: 5, configs: &configs, spikes: &bad })
        .unwrap_err();
    assert!(err.to_string().contains("spikes[2] = 2"), "{err}");
    // the batch validates independently of any backend too
    let batch = StepBatch { b: 1, n: 3, r: 5, configs: &configs, spikes: &bad };
    assert!(batch.validate().is_err());
}

#[test]
fn xla_matches_host_on_paper_pi_batches() {
    let manifest = require_artifacts!();
    let rt = PjRt::cpu().unwrap();
    let sys = snapse::generators::paper_pi();
    let m = build_matrix(&sys);
    let mut host = HostBackend::new(&m);
    let mut xla = snapse::compute::xla::backend_from_artifacts(rt, &m, &manifest).unwrap();
    let mut rng = Rng::new(0x5EED);
    for case in 0..20 {
        let b = rng.range(1, 40);
        let configs: Vec<i64> = (0..b * 3).map(|_| rng.range(0, 12) as i64).collect();
        // build per-neuron-valid spiking rows
        let mut spikes = vec![0u8; b * 5];
        for row in 0..b {
            for (neuron, rules) in [(0usize, 0..2usize), (1, 2..3), (2, 3..5)] {
                let _ = neuron;
                if rng.chance(0.7) {
                    let pick = rng.range(rules.start, rules.end - 1);
                    spikes[row * 5 + pick] = 1;
                }
            }
        }
        let batch = StepBatch { b, n: 3, r: 5, configs: &configs, spikes: &spikes };
        let h = host.step_batch(&batch).unwrap();
        let x = xla.step_batch(&batch).unwrap();
        assert_eq!(h, x, "case {case} (b={b})");
    }
}

#[test]
fn xla_matches_host_on_padded_shapes() {
    let manifest = require_artifacts!();
    let rt = PjRt::cpu().unwrap();
    // 6-neuron ring: (R, N) = (6, 6) → padded onto the (8, 8) artifact
    let sys = snapse::generators::ring(6, 2);
    let m = build_matrix(&sys);
    let mut host = HostBackend::new(&m);
    let mut xla = snapse::compute::xla::backend_from_artifacts(rt, &m, &manifest).unwrap();
    assert_eq!(xla.physical_shape(), (8, 8));
    let mut rng = Rng::new(77);
    for _ in 0..10 {
        let b = rng.range(1, 20);
        let configs: Vec<i64> = (0..b * 6).map(|_| rng.range(0, 5) as i64).collect();
        let spikes: Vec<u8> = (0..b * 6).map(|_| rng.chance(0.5) as u8).collect();
        let batch = StepBatch { b, n: 6, r: 6, configs: &configs, spikes: &spikes };
        assert_eq!(host.step_batch(&batch).unwrap(), xla.step_batch(&batch).unwrap());
    }
}

#[test]
fn full_exploration_identical_host_vs_xla() {
    let _ = require_artifacts!();
    let sys = snapse::generators::paper_pi();
    let mut host_coord = Coordinator::new(
        &sys,
        CoordinatorConfig { max_depth: Some(8), ..Default::default() },
    );
    let host_rep = host_coord.run().unwrap();
    let mut xla_coord = Coordinator::new(
        &sys,
        CoordinatorConfig {
            max_depth: Some(8),
            backend: BackendChoice::Xla { artifacts: "artifacts".into() },
            ..Default::default()
        },
    );
    let xla_rep = xla_coord.run().unwrap();
    assert_eq!(
        host_rep.visited.in_order(),
        xla_rep.visited.in_order(),
        "device and host explorations must be bit-identical"
    );
    assert_eq!(xla_rep.metrics.backend, "xla");
}

#[test]
fn exploration_on_branching_ring_device_path() {
    let _ = require_artifacts!();
    // R = N = 8: exact artifact shape, heavy Ψ branching
    let sys = snapse::generators::ring_with_branching(8, 1, 1);
    let mut host = Coordinator::new(&sys, CoordinatorConfig::default());
    let h = host.run().unwrap();
    let mut dev = Coordinator::new(
        &sys,
        CoordinatorConfig {
            backend: BackendChoice::Xla { artifacts: "artifacts".into() },
            ..Default::default()
        },
    );
    let d = dev.run().unwrap();
    assert_eq!(h.visited.in_order(), d.visited.in_order());
    assert_eq!(h.stop, d.stop);
}

#[test]
fn device_replay_matches_host_walks() {
    let manifest = require_artifacts!();
    let rt = PjRt::cpu().unwrap();
    for sys in [snapse::generators::paper_pi(), snapse::generators::nat_generator()] {
        for seed in 0..6u64 {
            for steps in [3usize, 8, 20, 50] {
                let rec = snapse::engine::RandomWalk::new(&sys, seed).run(steps);
                let replayed =
                    snapse::compute::replay_on_device(&rt, &manifest, &sys, &rec).unwrap();
                assert_eq!(
                    &replayed,
                    rec.path.last().unwrap(),
                    "{} seed {seed} steps {steps}",
                    sys.name
                );
                // verify_walk agrees (and errors would carry context)
                snapse::compute::verify_walk(&rt, &manifest, &sys, &rec).unwrap();
            }
        }
    }
}

#[test]
fn runtime_stats_track_traffic() {
    let manifest = require_artifacts!();
    let rt = PjRt::cpu().unwrap();
    let sys = snapse::generators::paper_pi();
    let m = build_matrix(&sys);
    let mut xla =
        snapse::compute::xla::backend_from_artifacts(rt.clone(), &m, &manifest).unwrap();
    let configs = vec![2i64, 1, 1];
    let spikes = vec![1u8, 0, 1, 1, 0];
    let _ =
        xla.step_batch(&StepBatch { b: 1, n: 3, r: 5, configs: &configs, spikes: &spikes });
    let stats = rt.stats();
    assert!(stats.executes >= 1);
    assert!(stats.elements_in > 0 && stats.elements_out > 0);
}
