//! Backend equivalence: the XLA/PJRT device path must agree bit-for-bit
//! with the pure-Rust host path on every workload. Requires
//! `make artifacts` (tests are skipped with a notice when absent, so
//! `cargo test` stays green on a fresh checkout).

use snapse::compute::{BackendFactory, HostBackend, SpikeBuf, SpikeRows, StepBackend, StepBatch};
use snapse::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use snapse::matrix::build_matrix;
use snapse::runtime::{Manifest, PjRt};
use snapse::util::Rng;

fn manifest() -> Option<Manifest> {
    Manifest::load(std::path::Path::new("artifacts")).ok()
}

macro_rules! require_artifacts {
    () => {
        match manifest() {
            Some(m) => m,
            None => {
                eprintln!("SKIP: no artifacts/ (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn non_binary_spiking_buffers_rejected_by_every_backend() {
    // StepBatch::validate enforces {0,1} spiking entries; the host backend
    // (and through the shared validation, the device backend) must reject
    // a buffer with a stray 2 instead of silently computing 2·M rows.
    let sys = snapse::generators::paper_pi();
    let m = build_matrix(&sys);
    let mut host = HostBackend::new(&m);
    let configs = vec![2i64, 1, 1];
    let good = vec![1u8, 0, 1, 1, 0];
    assert!(host
        .step_batch(&StepBatch { b: 1, n: 3, r: 5, configs: &configs, spikes: SpikeRows::Dense(&good) })
        .is_ok());
    let bad = vec![1u8, 0, 2, 1, 0];
    let err = host
        .step_batch(&StepBatch { b: 1, n: 3, r: 5, configs: &configs, spikes: SpikeRows::Dense(&bad) })
        .unwrap_err();
    assert!(err.to_string().contains("spikes[2] = 2"), "{err}");
    // the batch validates independently of any backend too
    let batch = StepBatch { b: 1, n: 3, r: 5, configs: &configs, spikes: SpikeRows::Dense(&bad) };
    assert!(batch.validate().is_err());
}

/// Build per-neuron-valid random spiking rows for `sys` in both
/// representations (dense bytes + CSR), plus the flat configs.
fn random_valid_rows(
    sys: &snapse::snp::SnpSystem,
    b: usize,
    rng: &mut Rng,
) -> (Vec<i64>, Vec<u8>, SpikeBuf) {
    let n = sys.num_neurons();
    let r = sys.num_rules();
    let configs: Vec<i64> = (0..b * n).map(|_| rng.range(0, 12) as i64).collect();
    let mut dense = vec![0u8; b * r];
    for row in 0..b {
        for j in 0..n {
            let rules = sys.rules_of(j);
            if rules.is_empty() || !rng.chance(0.7) {
                continue;
            }
            let pick = if rules.len() == 1 {
                rules.start
            } else {
                rng.range(rules.start, rules.end - 1)
            };
            dense[row * r + pick] = 1;
        }
    }
    let mut sparse = SpikeBuf::with_repr(true, r);
    for row in 0..b {
        sparse.push_byte_row(&dense[row * r..(row + 1) * r]);
    }
    (configs, dense, sparse)
}

#[test]
fn sparse_and_dense_rows_agree_on_every_host_repr() {
    // Randomized batches over systems spanning the density spectrum:
    // SpikeRows::Dense and SpikeRows::Sparse must produce identical
    // outputs on both host matrix representations (dense and CSR).
    let systems = [
        snapse::generators::paper_pi(),
        snapse::generators::ring_with_branching(6, 2, 2),
        snapse::generators::rule_heavy(6, 12, 2),
    ];
    let mut rng = Rng::new(0xCAB1E);
    for sys in &systems {
        let m = build_matrix(sys);
        let n = sys.num_neurons();
        let r = sys.num_rules();
        for case in 0..15 {
            let b = rng.range(1, 30);
            let (configs, dense, sparse) = random_valid_rows(sys, b, &mut rng);
            let batch =
                StepBatch { b, n, r, configs: &configs, spikes: SpikeRows::Dense(&dense) };
            let sparse_batch =
                StepBatch { b, n, r, configs: &configs, spikes: sparse.as_rows() };
            let dd = HostBackend::dense(&m).step_batch(&batch).unwrap();
            let ds = HostBackend::dense(&m).step_batch(&sparse_batch).unwrap();
            let cd = HostBackend::sparse(&m).step_batch(&batch).unwrap();
            let cs = HostBackend::sparse(&m).step_batch(&sparse_batch).unwrap();
            assert_eq!(dd, ds, "{} case {case}: dense matrix", sys.name);
            assert_eq!(dd, cd, "{} case {case}: csr matrix, dense rows", sys.name);
            assert_eq!(dd, cs, "{} case {case}: csr matrix, sparse rows", sys.name);
        }
    }
}

#[test]
fn delta_and_batch_agree_on_every_host_repr_and_spike_repr() {
    // Randomized batches over the three parameterless builtins plus a
    // rule-heavy system: `step_deltas_into` + parent-add must reproduce
    // `step_batch` bit-for-bit on both host matrix representations
    // (dense and CSR) and both spiking-row representations (dense bytes
    // and CSR fired lists).
    let systems = [
        snapse::generators::paper_pi(),
        snapse::generators::nat_generator(),
        snapse::generators::even_generator(),
        snapse::generators::rule_heavy(6, 12, 2),
    ];
    let mut rng = Rng::new(0xDE17A);
    for sys in &systems {
        let m = build_matrix(sys);
        let n = sys.num_neurons();
        let r = sys.num_rules();
        for case in 0..12 {
            let b = rng.range(1, 24);
            let (configs, dense, sparse) = random_valid_rows(sys, b, &mut rng);
            let dense_batch =
                StepBatch { b, n, r, configs: &configs, spikes: SpikeRows::Dense(&dense) };
            let sparse_batch =
                StepBatch { b, n, r, configs: &configs, spikes: sparse.as_rows() };
            for batch in [&dense_batch, &sparse_batch] {
                for mut be in [HostBackend::dense(&m), HostBackend::sparse(&m)] {
                    assert!(be.native_deltas());
                    let full = be.step_batch(batch).unwrap();
                    let mut deltas = Vec::new();
                    be.step_deltas_into(batch, &mut deltas).unwrap();
                    let applied: Vec<i64> =
                        configs.iter().zip(&deltas).map(|(c, d)| c + d).collect();
                    assert_eq!(
                        applied, full,
                        "{} case {case}: delta+parent != batch ({} matrix)",
                        sys.name,
                        be.repr_name()
                    );
                }
            }
        }
    }
}

#[test]
fn default_delta_adapter_matches_native_deltas() {
    // A custom backend without a native delta path: the trait's default
    // adapter (full rows minus parents) must agree with the host
    // backend's memoized native deltas on identical batches.
    struct Delegating(HostBackend);
    impl snapse::compute::StepBackend for Delegating {
        fn name(&self) -> &str {
            "delegating"
        }
        fn step_batch(
            &mut self,
            batch: &StepBatch<'_>,
        ) -> snapse::Result<Vec<i64>> {
            self.0.step_batch(batch)
        }
    }
    let sys = snapse::generators::rule_heavy(6, 12, 2);
    let m = build_matrix(&sys);
    let mut rng = Rng::new(0xADA);
    for case in 0..8 {
        let b = rng.range(1, 16);
        let (configs, dense, _) = random_valid_rows(&sys, b, &mut rng);
        let batch = StepBatch {
            b,
            n: sys.num_neurons(),
            r: sys.num_rules(),
            configs: &configs,
            spikes: SpikeRows::Dense(&dense),
        };
        let mut native = Vec::new();
        HostBackend::new(&m).step_deltas_into(&batch, &mut native).unwrap();
        let mut adapter = Delegating(HostBackend::new(&m));
        assert!(!snapse::compute::StepBackend::native_deltas(&adapter));
        let mut derived = Vec::new();
        adapter.step_deltas_into(&batch, &mut derived).unwrap();
        assert_eq!(derived, native, "case {case}");
    }
}

#[test]
fn malformed_sparse_rows_rejected_everywhere() {
    let sys = snapse::generators::paper_pi();
    let m = build_matrix(&sys);
    let configs = vec![2i64, 1, 1];
    let cases: &[(&str, &[u32], &[u32])] = &[
        ("out-of-range index", &[0, 1], &[9]),
        ("unsorted indices", &[0, 2], &[3, 0]),
        ("duplicate indices", &[0, 2], &[2, 2]),
        ("indptr too short", &[0], &[]),
        ("indptr/indices span mismatch", &[0, 3], &[0, 2]),
        ("decreasing indptr", &[2, 0], &[0, 1]),
    ];
    for &(what, indptr, indices) in cases {
        let batch = StepBatch {
            b: 1,
            n: 3,
            r: 5,
            configs: &configs,
            spikes: SpikeRows::Sparse { indptr, indices },
        };
        assert!(batch.validate().is_err(), "{what}: validate must reject");
        for mut be in [HostBackend::dense(&m), HostBackend::sparse(&m)] {
            assert!(be.step_batch(&batch).is_err(), "{what}: {} backend must reject", be.repr_name());
        }
    }
    // two fired rules in one neuron: structurally valid, caught by the
    // semantic per-neuron guard (rules 0 and 1 both live in neuron 0)
    let rule_neuron: Vec<usize> =
        (0..sys.num_neurons()).flat_map(|j| sys.rules_of(j).map(move |_| j)).collect();
    let batch = StepBatch {
        b: 1,
        n: 3,
        r: 5,
        configs: &configs,
        spikes: SpikeRows::Sparse { indptr: &[0, 2], indices: &[0, 1] },
    };
    assert!(batch.validate().is_ok());
    let err = batch.validate_one_rule_per_neuron(&rule_neuron).unwrap_err();
    assert!(err.to_string().contains("neuron 0"), "{err}");
}

#[test]
fn xla_matches_host_on_paper_pi_batches() {
    let manifest = require_artifacts!();
    let rt = PjRt::cpu().unwrap();
    let sys = snapse::generators::paper_pi();
    let m = build_matrix(&sys);
    let mut host = HostBackend::new(&m);
    let mut xla = snapse::compute::xla::backend_from_artifacts(rt, &m, &manifest).unwrap();
    let mut rng = Rng::new(0x5EED);
    for case in 0..20 {
        let b = rng.range(1, 40);
        let configs: Vec<i64> = (0..b * 3).map(|_| rng.range(0, 12) as i64).collect();
        // build per-neuron-valid spiking rows
        let mut spikes = vec![0u8; b * 5];
        for row in 0..b {
            for (neuron, rules) in [(0usize, 0..2usize), (1, 2..3), (2, 3..5)] {
                let _ = neuron;
                if rng.chance(0.7) {
                    let pick = rng.range(rules.start, rules.end - 1);
                    spikes[row * 5 + pick] = 1;
                }
            }
        }
        let batch =
            StepBatch { b, n: 3, r: 5, configs: &configs, spikes: SpikeRows::Dense(&spikes) };
        let h = host.step_batch(&batch).unwrap();
        let x = xla.step_batch(&batch).unwrap();
        assert_eq!(h, x, "case {case} (b={b})");
        // the CSR form of the same rows must marshal identically
        let mut sparse = SpikeBuf::with_repr(true, 5);
        for row in 0..b {
            sparse.push_byte_row(&spikes[row * 5..(row + 1) * 5]);
        }
        let sparse_batch =
            StepBatch { b, n: 3, r: 5, configs: &configs, spikes: sparse.as_rows() };
        assert_eq!(h, xla.step_batch(&sparse_batch).unwrap(), "case {case} sparse rows");
    }
}

#[test]
fn xla_factory_shares_compiles_and_upload() {
    let manifest = require_artifacts!();
    let rt = PjRt::cpu().unwrap();
    let sys = snapse::generators::paper_pi();
    let m = build_matrix(&sys);
    let stats_before = rt.stats();
    let factory = snapse::compute::XlaBackendFactory::new(rt.clone(), m, manifest);
    let mut first = factory.create().unwrap();
    let after_first = factory.compiled_count();
    let uploads_after_first = rt.stats().elements_in - stats_before.elements_in;
    assert!(after_first >= 1, "first create compiles the artifact ladder");
    // three more products: zero additional compiles, zero additional
    // matrix uploads (the device-resident padded matrix is shared)
    let mut others: Vec<_> = (0..3).map(|_| factory.create().unwrap()).collect();
    assert_eq!(factory.compiled_count(), after_first, "compiles stay flat");
    assert_eq!(
        rt.stats().elements_in - stats_before.elements_in,
        uploads_after_first,
        "matrix uploaded exactly once"
    );
    // and the shared-state products still compute correctly
    let configs = vec![2i64, 1, 1];
    let spikes = vec![1u8, 0, 1, 1, 0];
    let batch =
        StepBatch { b: 1, n: 3, r: 5, configs: &configs, spikes: SpikeRows::Dense(&spikes) };
    let want = first.step_batch(&batch).unwrap();
    for be in others.iter_mut() {
        assert_eq!(be.step_batch(&batch).unwrap(), want);
    }
}

#[test]
fn xla_matches_host_on_padded_shapes() {
    let manifest = require_artifacts!();
    let rt = PjRt::cpu().unwrap();
    // 6-neuron ring: (R, N) = (6, 6) → padded onto the (8, 8) artifact
    let sys = snapse::generators::ring(6, 2);
    let m = build_matrix(&sys);
    let mut host = HostBackend::new(&m);
    let mut xla = snapse::compute::xla::backend_from_artifacts(rt, &m, &manifest).unwrap();
    assert_eq!(xla.physical_shape(), (8, 8));
    let mut rng = Rng::new(77);
    for _ in 0..10 {
        let b = rng.range(1, 20);
        let configs: Vec<i64> = (0..b * 6).map(|_| rng.range(0, 5) as i64).collect();
        let spikes: Vec<u8> = (0..b * 6).map(|_| rng.chance(0.5) as u8).collect();
        let batch =
            StepBatch { b, n: 6, r: 6, configs: &configs, spikes: SpikeRows::Dense(&spikes) };
        assert_eq!(host.step_batch(&batch).unwrap(), xla.step_batch(&batch).unwrap());
    }
}

#[test]
fn full_exploration_identical_host_vs_xla() {
    let _ = require_artifacts!();
    let sys = snapse::generators::paper_pi();
    let mut host_coord = Coordinator::new(
        &sys,
        CoordinatorConfig { max_depth: Some(8), ..Default::default() },
    );
    let host_rep = host_coord.run().unwrap();
    let mut xla_coord = Coordinator::new(
        &sys,
        CoordinatorConfig {
            max_depth: Some(8),
            backend: BackendChoice::Xla { artifacts: "artifacts".into() },
            ..Default::default()
        },
    );
    let xla_rep = xla_coord.run().unwrap();
    assert_eq!(
        host_rep.visited.in_order(),
        xla_rep.visited.in_order(),
        "device and host explorations must be bit-identical"
    );
    assert_eq!(xla_rep.metrics.backend, "xla");
}

#[test]
fn exploration_on_branching_ring_device_path() {
    let _ = require_artifacts!();
    // R = N = 8: exact artifact shape, heavy Ψ branching
    let sys = snapse::generators::ring_with_branching(8, 1, 1);
    let mut host = Coordinator::new(&sys, CoordinatorConfig::default());
    let h = host.run().unwrap();
    let mut dev = Coordinator::new(
        &sys,
        CoordinatorConfig {
            backend: BackendChoice::Xla { artifacts: "artifacts".into() },
            ..Default::default()
        },
    );
    let d = dev.run().unwrap();
    assert_eq!(h.visited.in_order(), d.visited.in_order());
    assert_eq!(h.stop, d.stop);
}

#[test]
fn device_replay_matches_host_walks() {
    let manifest = require_artifacts!();
    let rt = PjRt::cpu().unwrap();
    for sys in [snapse::generators::paper_pi(), snapse::generators::nat_generator()] {
        for seed in 0..6u64 {
            for steps in [3usize, 8, 20, 50] {
                let rec = snapse::engine::RandomWalk::new(&sys, seed).run(steps);
                let replayed =
                    snapse::compute::replay_on_device(&rt, &manifest, &sys, &rec).unwrap();
                assert_eq!(
                    &replayed,
                    rec.path.last().unwrap(),
                    "{} seed {seed} steps {steps}",
                    sys.name
                );
                // verify_walk agrees (and errors would carry context)
                snapse::compute::verify_walk(&rt, &manifest, &sys, &rec).unwrap();
            }
        }
    }
}

#[test]
fn runtime_stats_track_traffic() {
    let manifest = require_artifacts!();
    let rt = PjRt::cpu().unwrap();
    let sys = snapse::generators::paper_pi();
    let m = build_matrix(&sys);
    let mut xla =
        snapse::compute::xla::backend_from_artifacts(rt.clone(), &m, &manifest).unwrap();
    let configs = vec![2i64, 1, 1];
    let spikes = vec![1u8, 0, 1, 1, 0];
    let _ = xla.step_batch(&StepBatch {
        b: 1,
        n: 3,
        r: 5,
        configs: &configs,
        spikes: SpikeRows::Dense(&spikes),
    });
    let stats = rt.stats();
    assert!(stats.executes >= 1);
    assert!(stats.elements_in > 0 && stats.elements_out > 0);
}
