//! Store-mode equivalence: the compressed visited arena and the run-scoped
//! delta cache are pure memory/speed optimizations — every observable output
//! (config ids, `allGenCk` order, rendered reports, JSON) must be
//! byte-identical to the plain-store reference at every worker count, in
//! both search orders, with the cache on or off. Plus randomized round-trip
//! fuzzing of the varint/parent-delta encoder itself on adversarial counts.

use snapse::engine::{
    ConfigStore, ExploreOptions, Explorer, SearchOrder, SpillConfig, SpillShared, StoreMode,
};
use snapse::snp::SnpSystem;
use snapse::util::Rng;

const WORKER_COUNTS: [usize; 3] = [1, 4, 8];

fn systems() -> Vec<SnpSystem> {
    vec![
        snapse::generators::paper_pi(),
        snapse::generators::rule_heavy(6, 12, 2),
        snapse::generators::wide_ring(6, 3, 2),
    ]
}

fn opts(order: SearchOrder) -> ExploreOptions {
    match order {
        SearchOrder::BreadthFirst => ExploreOptions::breadth_first(),
        SearchOrder::DepthFirst => ExploreOptions::depth_first(),
    }
}

/// All observable renderings of a run, concatenated for one-shot equality.
fn observe(sys: &SnpSystem, o: ExploreOptions) -> String {
    let rep = Explorer::new(sys, o).run();
    let mut s = String::new();
    for c in rep.visited.in_order() {
        s.push_str(&c.to_string());
        s.push('\n');
    }
    s.push_str(&rep.visited.render_all_gen_ck());
    s.push('\n');
    s.push_str(&rep.to_json(&sys.name).to_string_compact());
    s.push('\n');
    s.push_str(&format!("{}|{}|{:?}", rep.stop, rep.depth_reached, rep.halting_configs));
    s
}

#[test]
fn compressed_store_identical_across_systems_workers_orders() {
    for sys in systems() {
        for order in [SearchOrder::BreadthFirst, SearchOrder::DepthFirst] {
            let reference = observe(&sys, opts(order).max_configs(400));
            for w in WORKER_COUNTS {
                let got = observe(
                    &sys,
                    opts(order)
                        .max_configs(400)
                        .workers(w)
                        .store_mode(StoreMode::Compressed),
                );
                assert_eq!(
                    got, reference,
                    "{} {order:?}: compressed store diverged at workers={w}",
                    sys.name
                );
            }
        }
    }
}

/// The tentpole contract: the disk-spillable store is byte-identical to
/// the plain reference at every observable surface, in both orders, at
/// 1 and 4 workers — with budgets small enough that cold segments are
/// demonstrably evicted to disk and faulted back mid-run.
#[test]
fn spill_store_identical_across_systems_workers_orders_and_budgets() {
    for sys in systems() {
        for order in [SearchOrder::BreadthFirst, SearchOrder::DepthFirst] {
            let reference = observe(&sys, opts(order).max_configs(400));
            for w in [1usize, 4] {
                for budget in [1u64, 4096] {
                    let got = observe(
                        &sys,
                        opts(order)
                            .max_configs(400)
                            .workers(w)
                            .store_mode(StoreMode::Spill)
                            .spill_budget(budget),
                    );
                    assert_eq!(
                        got, reference,
                        "{} {order:?}: spill store diverged at workers={w} budget={budget}",
                        sys.name
                    );
                }
            }
        }
    }
}

/// At a 1-byte budget the spill tier must actually evict and fault on
/// these workloads — identity alone could be trivially satisfied by a
/// tier that never leaves RAM.
#[test]
fn tiny_budget_runs_do_evict_and_fault() {
    // wide_ring(6,3,2) closes below one minimum segment (512 B) and can
    // never seal, so the eviction assertion uses the two workloads whose
    // capped arenas always exceed it
    for sys in [snapse::generators::paper_pi(), snapse::generators::rule_heavy(6, 12, 2)] {
        let rep = Explorer::new(
            &sys,
            ExploreOptions::breadth_first()
                .max_configs(400)
                .store_mode(StoreMode::Spill)
                .spill_budget(1),
        )
        .run();
        assert!(rep.stats.spilled_bytes > 0, "{}: nothing spilled", sys.name);
        assert!(rep.stats.spill_faults > 0, "{}: nothing faulted back", sys.name);
    }
}

#[test]
fn delta_cache_on_off_identical_across_systems_workers() {
    for sys in systems() {
        let reference =
            observe(&sys, ExploreOptions::breadth_first().max_configs(400).delta_cache(0));
        for w in WORKER_COUNTS {
            for cap in [0usize, 64, snapse::compute::DEFAULT_DELTA_CACHE] {
                let got = observe(
                    &sys,
                    ExploreOptions::breadth_first()
                        .max_configs(400)
                        .workers(w)
                        .delta_cache(cap)
                        .store_mode(StoreMode::Compressed),
                );
                assert_eq!(
                    got, reference,
                    "{}: delta_cache={cap} workers={w} diverged",
                    sys.name
                );
            }
        }
    }
}

#[test]
fn disabled_cache_reports_zero_counters() {
    let sys = snapse::generators::paper_pi();
    let rep = Explorer::new(
        &sys,
        ExploreOptions::breadth_first().max_configs(200).delta_cache(0),
    )
    .run();
    assert_eq!(rep.stats.delta_cache_capacity, 0);
    assert_eq!((rep.stats.delta_hits, rep.stats.delta_misses), (0, 0));
    let rep = Explorer::new(&sys, ExploreOptions::breadth_first().max_configs(200)).run();
    assert!(rep.stats.delta_cache_capacity > 0);
    assert!(rep.stats.delta_hits + rep.stats.delta_misses > 0);
}

/// Adversarial counts for the varint/zigzag edge cases: zero, small,
/// every byte-length boundary of LEB128, and wrap-prone extremes.
const EDGE: [u64; 12] = [
    0,
    1,
    2,
    127,
    128,
    16_383,
    16_384,
    u32::MAX as u64,
    u64::MAX / 2,
    u64::MAX - 1,
    u64::MAX,
    0x8000_0000_0000_0000,
];

#[test]
fn compressed_round_trip_fuzz_against_plain_mirror() {
    let seed = 0xC0FF_EE11u64;
    println!("seed = {seed:#x}");
    let mut rng = Rng::new(seed);
    // cumulative spill traffic: individual tiny-width trials may fit in
    // one open segment, but across 50 trials eviction must have happened
    let (mut spilled_total, mut faults_total) = (0u64, 0u64);
    for trial in 0..50 {
        let width = rng.range(1, 40);
        let mut plain = ConfigStore::with_mode(StoreMode::Plain);
        let mut comp = ConfigStore::with_mode(StoreMode::Compressed);
        // third mirror: the spill store at a 1-byte budget, so cold
        // segments are evicted to disk and faulted back all trial long
        let mut sp = ConfigStore::with_spill_capacity(
            width,
            16,
            SpillShared::new(&SpillConfig { dir: None, budget: 1 }),
        );
        let mut rows: Vec<Vec<u64>> = Vec::new();
        let mut prev: Vec<u64> = (0..width).map(|_| *rng.choose(&EDGE)).collect();
        for step in 0..200 {
            let row: Vec<u64> = if !rows.is_empty() && rng.chance(0.2) {
                // exact duplicate: both stores must agree it's old
                rng.choose(&rows).clone()
            } else if rng.chance(0.6) {
                // sparse mutation of the previous row — the parent-delta
                // encoder's target shape, with wrap-prone jumps
                let mut r = prev.clone();
                for _ in 0..rng.range(1, (width / 4).max(1)) {
                    let i = rng.range(0, width - 1);
                    r[i] = if rng.chance(0.5) {
                        *rng.choose(&EDGE)
                    } else {
                        r[i].wrapping_add(rng.next_u64())
                    };
                }
                r
            } else {
                // fresh random row (full-row fallback territory)
                (0..width).map(|_| rng.next_u64()).collect()
            };
            // parent: usually the previous id (delta chains), sometimes
            // an old id (chain sharing), sometimes none (full row)
            let parent = if rows.is_empty() || rng.chance(0.15) {
                None
            } else if rng.chance(0.8) {
                Some((plain.len() - 1) as u32)
            } else {
                Some(rng.range(0, plain.len() - 1) as u32)
            };
            let (pid, pnew) = plain.intern_with_parent(&row, parent);
            let (cid, cnew) = comp.intern_with_parent(&row, parent);
            assert_eq!(
                (pid, pnew),
                (cid, cnew),
                "trial {trial} step {step}: id/newness diverged for {row:?}"
            );
            let (sid, snew) = sp
                .try_intern_with_parent(&row, parent)
                .expect("healthy spill file never errors");
            assert_eq!(
                (pid, pnew),
                (sid, snew),
                "trial {trial} step {step}: spill id/newness diverged for {row:?}"
            );
            if pnew {
                rows.push(row.clone());
            }
            prev = row;
        }
        // full read-back sweep: every id decodes to the row it interned
        let mut buf = Vec::new();
        for (id, want) in rows.iter().enumerate() {
            comp.get_into(id as u32, &mut buf);
            assert_eq!(&buf, want, "trial {trial}: id {id} decoded wrong");
            assert_eq!(plain.get(id as u32), want.as_slice());
            assert_eq!(comp.find(want), Some(id as u32), "trial {trial}: find missed id {id}");
            sp.try_get_into(id as u32, &mut buf).expect("spill decode");
            assert_eq!(&buf, want, "trial {trial}: spill id {id} decoded wrong");
            assert_eq!(
                sp.try_find(want).expect("spill find"),
                Some(id as u32),
                "trial {trial}: spill find missed id {id}"
            );
        }
        assert_eq!(comp.len(), plain.len());
        assert_eq!(sp.len(), plain.len());
        // structural audit (debug builds): table↔arena bijection, chain
        // caps, segment containment — in all three modes
        plain.check_invariants();
        comp.check_invariants();
        sp.check_invariants();
        let st = sp.spill_stats().expect("spill store reports stats");
        spilled_total += st.spilled_bytes;
        faults_total += st.faults;
        // compressed cursor yields the exact interning order
        let mut cur = comp.rows();
        let mut i = 0usize;
        while let Some(r) = cur.next_row() {
            assert_eq!(r, rows[i].as_slice(), "trial {trial}: cursor row {i}");
            i += 1;
        }
        assert_eq!(i, rows.len());
    }
    assert!(spilled_total > 0, "no trial ever evicted a segment");
    assert!(faults_total > 0, "no trial ever faulted a segment back in");
}

/// A truncated spill file must surface a structured `Error` on fault-in
/// — never a panic — and leave the store usable for resident segments.
#[test]
fn truncated_spill_file_surfaces_structured_error_not_panic() {
    let shared = SpillShared::new(&SpillConfig { dir: None, budget: 1 });
    let mut sp = ConfigStore::with_spill_capacity(16, 64, shared);
    let mut rows: Vec<Vec<u64>> = Vec::new();
    for i in 0..2_000u64 {
        let row: Vec<u64> = (0..16).map(|j| i.wrapping_mul(0x9E37_79B9).wrapping_add(j)).collect();
        sp.try_intern(&row).expect("healthy spill file never errors");
        rows.push(row);
    }
    assert!(sp.spill_stats().expect("stats").spilled_bytes > 0, "budget 1 must spill");
    let path = sp.spill_file().expect("an eviction created the file");
    std::fs::OpenOptions::new()
        .write(true)
        .open(&path)
        .expect("open spill file")
        .set_len(1)
        .expect("truncate spill file");
    let mut buf = Vec::new();
    let err = (0..rows.len() as u32)
        .find_map(|id| sp.try_get_into(id, &mut buf).err())
        .expect("some id must fault from the truncated file");
    let msg = err.to_string();
    assert!(
        msg.contains("io error") || msg.contains("spill"),
        "structured error names the failure: {msg}"
    );
}

/// Dropping the last holder of a spill run removes its file — tiered
/// runs never leak disk.
#[test]
fn spill_file_is_removed_when_the_store_drops() {
    let shared = SpillShared::new(&SpillConfig { dir: None, budget: 1 });
    let mut sp = ConfigStore::with_spill_capacity(8, 64, shared);
    for i in 0..2_000u64 {
        let row: Vec<u64> = (0..8).map(|j| i * 131 + j).collect();
        sp.try_intern(&row).expect("healthy interning");
    }
    let path = sp.spill_file().expect("an eviction created the file");
    assert!(path.exists(), "spill file on disk while the store lives");
    drop(sp);
    assert!(!path.exists(), "spill file removed with its last holder");
}

#[test]
fn edge_values_survive_long_parent_chains() {
    // a deliberate worst case: a long chain of single-column mutations
    // cycling through every adversarial value, forcing chain-bounded
    // re-anchoring (full-row fallback) along the way
    let width = 8;
    let mut comp = ConfigStore::with_mode(StoreMode::Compressed);
    let mut rows: Vec<Vec<u64>> = Vec::new();
    let mut cur = vec![0u64; width];
    let (mut parent, fresh) = comp.intern_with_parent(&cur, None);
    assert!(fresh);
    rows.push(cur.clone());
    for (step, &v) in EDGE.iter().cycle().take(120).enumerate() {
        cur[step % width] = v ^ (step as u64) << 32;
        let (id, fresh) = comp.intern_with_parent(&cur, Some(parent));
        if fresh {
            rows.push(cur.clone());
            parent = id;
        }
    }
    let mut buf = Vec::new();
    for (id, want) in rows.iter().enumerate() {
        comp.get_into(id as u32, &mut buf);
        assert_eq!(&buf, want, "chain id {id}");
    }
    comp.check_invariants();
}

#[test]
fn delta_cache_invariants_hold_under_concurrent_use() {
    use snapse::compute::DeltaCache;
    use std::sync::Arc;
    let cache = Arc::new(DeltaCache::new(96, 5, 32));
    std::thread::scope(|scope| {
        for t in 0..4usize {
            let cache = Arc::clone(&cache);
            scope.spawn(move || {
                let mut row = vec![0i64; 5];
                for i in 0..300usize {
                    let bit = (t * 13 + i) % 96;
                    let mut key = vec![0u64; cache.key_words()];
                    key[bit >> 6] |= 1u64 << (bit & 63);
                    if !cache.lookup(&key, &mut row) {
                        let v = bit as i64 + 1;
                        cache.insert(&key, &[v, -v, v, -v, v]);
                    }
                }
            });
        }
    });
    cache.check_invariants();
    let stats = cache.stats();
    assert!(stats.entries <= 32);
    assert_eq!(stats.hits + stats.misses, 1200);
}
