//! E2 — integration test: the Figure-4 computation tree.

use snapse::engine::{ConfigVector, ExploreOptions, Explorer};

fn pi_tree(depth: u32) -> snapse::engine::ComputationTree {
    let sys = snapse::generators::paper_pi();
    Explorer::new(&sys, ExploreOptions::breadth_first().max_depth(depth).with_tree())
        .run()
        .tree
        .unwrap()
}

#[test]
fn figure4_top_levels() {
    // Figure 4 shows: 2-1-1 → {2-1-2, 1-1-2}; 2-1-2 → {2-1-3, 1-1-3, and
    // repeats of 2-1-2/1-1-2}; 1-1-2 → {2-0-2, 2-0-1}.
    let t = pi_tree(2);
    let root = t.root().unwrap();
    let c = |s: &str| ConfigVector::parse_dashed(s).unwrap();

    let kids: Vec<String> = t.children(root).map(|e| t.config(e.to).to_string()).collect();
    assert_eq!(kids, vec!["2-1-2", "1-1-2"]);

    let n212 = t.node_of(&c("2-1-2")).unwrap();
    let mut kids212: Vec<String> =
        t.children(n212).map(|e| t.config(e.to).to_string()).collect();
    kids212.sort();
    kids212.dedup();
    assert_eq!(kids212, vec!["1-1-2", "1-1-3", "2-1-2", "2-1-3"]);

    let n112 = t.node_of(&c("1-1-2")).unwrap();
    let kids112: Vec<String> =
        t.children(n112).map(|e| t.config(e.to).to_string()).collect();
    assert_eq!(kids112, vec!["2-0-2", "2-0-1"]);
}

#[test]
fn per_depth_discovery_histogram() {
    // Verified against the BFS levels of the paper's allGenCk: 1 root,
    // 2 at depth 1, 4 at depth 2, 6 at depth 3, then 6,6 and 5s.
    let t = pi_tree(9);
    assert_eq!(t.histogram(), vec![1, 2, 4, 6, 6, 6, 5, 5, 5, 5]);
    assert_eq!(t.num_nodes(), 45);
}

#[test]
fn cross_edges_mark_repeats() {
    // Fig. 4 draws repeated configurations as leaves; we record them as
    // cross (non-discovery) edges. 2-1-2 firing (1)(3)(5) loops to itself.
    let t = pi_tree(2);
    let c = |s: &str| ConfigVector::parse_dashed(s).unwrap();
    let n212 = t.node_of(&c("2-1-2")).unwrap();
    let self_loop = t
        .edges()
        .iter()
        .any(|e| e.from == n212 && e.to == n212 && !e.discovered);
    assert!(self_loop, "2-1-2 →(10101) 2-1-2 recorded as cross edge");
}

#[test]
fn dot_export_is_well_formed() {
    let t = pi_tree(3);
    let dot = t.to_dot("pi");
    assert!(dot.starts_with("digraph"));
    assert!(dot.ends_with("}\n"));
    let nodes = dot.lines().filter(|l| l.contains("[label=") && !l.contains("->")).count();
    assert_eq!(nodes, t.num_nodes());
    let edges = dot.lines().filter(|l| l.contains(" -> ")).count();
    assert_eq!(edges, t.num_edges());
}

#[test]
fn json_export_has_all_nodes_and_depths() {
    let t = pi_tree(3);
    let j = t.to_json();
    let parsed = snapse::util::JsonValue::parse(&j.to_string_compact()).unwrap();
    let nodes = parsed.get("nodes").unwrap().as_arr().unwrap();
    assert_eq!(nodes.len(), t.num_nodes());
    // root at depth 0
    assert_eq!(nodes[0].get("depth").unwrap().as_usize(), Some(0));
    assert_eq!(nodes[0].get("config").unwrap().as_str(), Some("2-1-1"));
}

#[test]
fn leaves_are_halting_or_frontier() {
    let sys = snapse::generators::counter_chain(3, 2);
    let rep = Explorer::new(&sys, ExploreOptions::breadth_first().with_tree()).run();
    let t = rep.tree.unwrap();
    let leaves = t.leaves();
    assert_eq!(leaves.len(), 1, "deterministic chain has one leaf");
    assert!(t.config(leaves[0]).is_zero());
}
