//! E1 — integration test: the paper's §5 `allGenCk` reproduction.

use snapse::engine::{ConfigVector, ExploreOptions, Explorer, StopReason};

/// The paper's §5 final `allGenCk`, verbatim (48 entries).
const PAPER_ALL_GEN_CK: &[&str] = &[
    "2-1-1", "2-1-2", "1-1-2", "2-1-3", "1-1-3", "2-0-2", "2-0-1", "2-1-4", "1-1-4", "2-0-3",
    "1-1-1", "0-1-2", "0-1-1", "2-1-5", "1-1-5", "2-0-4", "0-1-3", "1-0-2", "1-0-1", "2-1-6",
    "1-1-6", "2-0-5", "0-1-4", "1-0-3", "1-0-0", "2-1-7", "1-1-7", "2-0-6", "0-1-5", "1-0-4",
    "2-1-8", "1-1-8", "2-0-7", "0-1-6", "1-0-5", "2-1-9", "1-1-9", "2-0-8", "0-1-7", "1-0-6",
    "2-1-10", "1-1-10", "2-0-9", "0-1-8", "1-0-7", "0-1-9", "1-0-8", "1-0-9",
];

#[test]
fn bfs_depth9_reproduces_the_first_45_entries_in_order() {
    let sys = snapse::generators::paper_pi();
    let rep = Explorer::new(&sys, ExploreOptions::breadth_first().max_depth(9)).run();
    let ours: Vec<String> = rep.visited.in_order().iter().map(|c| c.to_string()).collect();
    assert_eq!(ours.len(), 45);
    assert_eq!(
        ours,
        PAPER_ALL_GEN_CK[..45].iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        "exact order match with the paper's published log"
    );
    assert_eq!(rep.stop, StopReason::MaxDepth);
}

#[test]
fn all_48_paper_configs_are_reachable() {
    let sys = snapse::generators::paper_pi();
    let rep = Explorer::new(&sys, ExploreOptions::breadth_first().max_depth(11)).run();
    for name in PAPER_ALL_GEN_CK {
        let c = ConfigVector::parse_dashed(name).unwrap();
        assert!(rep.visited.contains(&c), "paper config {name} not reached");
    }
}

#[test]
fn paper_tail_entries_come_from_the_depth9_frontier() {
    // The paper's last three entries ('0-1-9', '1-0-8', '1-0-9') are the
    // children of 2-0-9 / 0-1-8 / 0-1-9 — i.e. its final level was only
    // partially expanded. Verify the parentage claims.
    let sys = snapse::generators::paper_pi();
    let m = snapse::matrix::build_matrix(&sys);
    // 2-0-9, firing rules (2)(4): [2,0,9] + [-2,1,1] + [0,0,-1] = [0,1,9]
    let child = m.step(&[2, 0, 9], &[0, 1, 0, 1, 0]).unwrap();
    assert_eq!(child, vec![0, 1, 9]);
    // 0-1-8, firing rules (3)(4): [0,1,8] + [1,-1,1] + [0,0,-1] = [1,0,8]
    let child = m.step(&[0, 1, 8], &[0, 0, 1, 1, 0]).unwrap();
    assert_eq!(child, vec![1, 0, 8]);
    // 0-1-9, firing rules (3)(4): [0,1,9] + [1,-1,1] + [0,0,-1] = [1,0,9]
    let child = m.step(&[0, 1, 9], &[0, 0, 1, 1, 0]).unwrap();
    assert_eq!(child, vec![1, 0, 9]);
}

#[test]
fn paper_log_rendering_matches_section5_fields() {
    let sys = snapse::generators::paper_pi();
    let rep = Explorer::new(&sys, ExploreOptions::breadth_first().max_depth(9)).run();
    let log = snapse::output::render_paper_log(&sys, &rep);
    // the fields the paper prints
    assert!(log.contains("Initial configuration vector: 211"));
    assert!(log.contains("Number of neurons for the SN P system is 3"));
    assert!(log.contains("['2', '2', '$', '1', '$', '1', '2']"), "the r file rendering");
    assert!(log.contains("'10110', '01110'"), "C0's valid spiking vectors");
    assert!(log.contains("'2-1-1', '2-1-2', '1-1-2'"), "allGenCk prefix");
}

#[test]
fn unbounded_exploration_would_not_terminate_fast() {
    // Π generates an infinite set; with a 500-config budget the run must
    // stop on the budget, not on exhaustion.
    let sys = snapse::generators::paper_pi();
    let rep = Explorer::new(&sys, ExploreOptions::breadth_first().max_configs(500)).run();
    assert_eq!(rep.stop, StopReason::MaxConfigs);
    assert!(rep.visited.len() >= 500);
}

#[test]
fn dfs_reaches_the_same_45_set_as_bfs_at_depth9() {
    let sys = snapse::generators::paper_pi();
    let bfs = Explorer::new(&sys, ExploreOptions::breadth_first().max_depth(9)).run();
    let dfs = Explorer::new(&sys, ExploreOptions::depth_first().max_depth(9)).run();
    let mut a: Vec<String> = bfs.visited.in_order().iter().map(|c| c.to_string()).collect();
    let mut b: Vec<String> = dfs.visited.in_order().iter().map(|c| c.to_string()).collect();
    a.sort();
    b.sort();
    // DFS with a depth bound reaches a subset of the BFS-depth-9 cone that
    // includes all shallow nodes; on Π they coincide exactly.
    assert_eq!(a, b);
}
