// lint: module serve::fixture
// L1 trigger: a raw `.unwrap()` on a lock in daemon-scope code.
// This file is lint corpus only — it is never compiled.

fn handler(state: &std::sync::Mutex<u32>) -> u32 {
    let guard = state.lock().unwrap();
    *guard
}
