// lint: module compute::fixture
// L4 trigger: a span name outside the fixed phase vocabulary.
// This file is lint corpus only — it is never compiled.

fn instrument(trace: &snapse::obs::Trace) {
    trace.event(None, "warmup", &[]);
}
