// lint: module serve::fixture
// Bad-allow case: the allow matches but gives no justification, which
// is itself a finding. This file is lint corpus only — never compiled.

fn handler(xs: &[u32]) -> u32 {
    // lint: allow(L1)
    *xs.first().unwrap()
}
