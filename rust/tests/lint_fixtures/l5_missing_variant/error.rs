// L5 fixture: a taxonomy with one variant the router never maps.
// This file is lint corpus only — it is never compiled.

#[derive(Debug)]
pub enum Error {
    Io(String),
    Parse { line: u32 },
    Unmapped(String),
}
