// L5 fixture: the status mapping that forgot `Unmapped`.
// This file is lint corpus only — it is never compiled.

fn error_response(e: &Error) -> (u16, &'static str) {
    match e {
        Error::Io(_) => (500, "io"),
        Error::Parse { .. } => (400, "parse"),
        _ => (500, "internal"),
    }
}
