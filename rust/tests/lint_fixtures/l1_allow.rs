// lint: module serve::fixture
// Clean case: the same panicking call, excused by a justified allow.
// This file is lint corpus only — it is never compiled.

fn handler(xs: &[u32]) -> u32 {
    // lint: allow(L1) — slice is non-empty by construction (caller validates)
    *xs.first().unwrap()
}
