// lint: module engine::fixture
// L6 trigger: an `unsafe` block with no SAFETY comment.
// This file is lint corpus only — it is never compiled.

fn read_first(xs: &[u32]) -> u32 {
    unsafe { *xs.as_ptr() }
}
