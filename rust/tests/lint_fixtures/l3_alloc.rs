// lint: module engine::fixture
// L3 trigger: a per-child allocation inside a hotpath fence.
// This file is lint corpus only — it is never compiled.

fn fold(children: &[Vec<u64>]) -> Vec<Vec<u64>> {
    let mut out = Vec::new();
    // lint: hotpath — steady-state loop must not allocate per child
    for child in children {
        out.push(child.clone());
    }
    // lint: hotpath-end
    out
}
