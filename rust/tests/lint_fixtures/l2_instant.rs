// lint: module engine::fixture
// L2 trigger: an ungated timer syscall outside obs/util::cancel.
// This file is lint corpus only — it is never compiled.

use std::time::Instant;

fn step() -> u64 {
    let t0 = Instant::now();
    t0.elapsed().as_micros() as u64
}
