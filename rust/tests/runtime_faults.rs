//! Failure injection: the runtime must fail cleanly (typed errors, no
//! panics, no poisoned state) on corrupt artifacts and misuse.

use snapse::runtime::{Arg, Manifest, PjRt};

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("snapse_faults_{name}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn corrupt_hlo_text_fails_cleanly() {
    let dir = tmpdir("corrupt");
    let path = dir.join("bad.hlo.txt");
    std::fs::write(&path, "HloModule nonsense\n\nENTRY {]").unwrap();
    let rt = PjRt::cpu().unwrap();
    let err = rt.compile_step(&path).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("artifact") || msg.contains("runtime"), "{msg}");
    // the runtime thread must survive the failure
    assert!(!rt.platform().is_empty());
    assert_eq!(rt.stats().compiles, 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn empty_file_fails_cleanly() {
    let dir = tmpdir("empty");
    let path = dir.join("empty.hlo.txt");
    std::fs::write(&path, "").unwrap();
    let rt = PjRt::cpu().unwrap();
    assert!(rt.compile_step(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn executing_with_wrong_arity_fails_cleanly() {
    // valid artifact, wrong argument count/shape
    let Ok(manifest) = Manifest::load(std::path::Path::new("artifacts")) else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let rt = PjRt::cpu().unwrap();
    let entry = &manifest.step_entries(5, 3)[0];
    let exec = rt.compile_step(&entry.path).unwrap();
    // one arg instead of three
    let err = rt
        .execute_f32(exec, vec![Arg::Host { data: vec![0.0; 5], dims: vec![1, 5] }])
        .unwrap_err();
    assert!(err.to_string().contains("runtime"), "{err}");
    // runtime still serves correct requests afterwards
    let ok = rt.execute_f32(
        exec,
        vec![
            Arg::Host { data: vec![0.0; 5], dims: vec![1, 5] },
            Arg::Host { data: vec![0.0; 15], dims: vec![5, 3] },
            Arg::Host { data: vec![7.0, 8.0, 9.0], dims: vec![1, 3] },
        ],
    );
    assert_eq!(ok.unwrap(), vec![7.0, 8.0, 9.0]);
}

#[test]
fn bad_device_buffer_id_fails_cleanly() {
    let Ok(manifest) = Manifest::load(std::path::Path::new("artifacts")) else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let rt = PjRt::cpu().unwrap();
    let entry = &manifest.step_entries(5, 3)[0];
    let exec = rt.compile_step(&entry.path).unwrap();
    // upload a buffer on a DIFFERENT runtime, then use its id here — the
    // handle indexes this runtime's (empty) table
    let other = PjRt::cpu().unwrap();
    let foreign = other.upload(vec![0.0; 15], vec![5, 3]).unwrap();
    let err = rt
        .execute_f32(
            exec,
            vec![
                Arg::Host { data: vec![0.0; 5], dims: vec![1, 5] },
                Arg::Device(foreign),
                Arg::Host { data: vec![0.0; 3], dims: vec![1, 3] },
            ],
        )
        .unwrap_err();
    assert!(err.to_string().contains("bad buffer id"), "{err}");
}

#[test]
fn upload_shape_mismatch_fails() {
    let rt = PjRt::cpu().unwrap();
    assert!(rt.upload(vec![1.0, 2.0, 3.0], vec![2, 2]).is_err());
}

#[test]
fn manifest_entry_pointing_nowhere() {
    let dir = tmpdir("dangling");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"entries":[{"kind":"step","r":5,"n":3,"b":1,"path":"missing.hlo.txt"}]}"#,
    )
    .unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let rt = PjRt::cpu().unwrap();
    let sys = snapse::generators::paper_pi();
    let m = snapse::matrix::build_matrix(&sys);
    let err = match snapse::compute::xla::backend_from_artifacts(rt, &m, &manifest) {
        Err(e) => e,
        Ok(_) => panic!("dangling artifact path must fail"),
    };
    assert!(err.to_string().contains("artifact"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn malformed_manifest_variants() {
    let p = std::path::Path::new("/x");
    assert!(Manifest::parse(p, "not json").is_err());
    assert!(Manifest::parse(p, r#"{"entries": 42}"#).is_err());
    assert!(Manifest::parse(p, r#"{"entries":[{"r":"five"}]}"#).is_err());
    assert!(Manifest::parse(p, r#"{"entries":[{"r":5,"n":3,"b":1}]}"#).is_err(), "no path");
}
