//! `snapse-lint` golden tests: the repository's own sources must pass
//! the contract linter clean, and every rule must fire on its fixture.
//!
//! This is the same check CI runs as its first gate
//! (`cargo run --release --bin snapse-lint -- --check`), kept in-suite
//! so `cargo test` alone catches contract regressions.

use std::path::{Path, PathBuf};

use snapse::lint::{self, rules};

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn fixture(name: &str) -> PathBuf {
    repo_root().join("rust/tests/lint_fixtures").join(name)
}

/// The golden invariant: the tree this test compiled from is clean.
#[test]
fn repository_passes_clean() {
    let report = lint::run(repo_root());
    assert!(
        report.files_scanned > 40,
        "expected to scan the whole rust/src tree, saw {} files",
        report.files_scanned
    );
    assert!(
        report.is_clean(),
        "snapse-lint found contract violations in the repository:\n{}",
        report.to_table()
    );
}

/// Every per-file rule fires on its dedicated fixture.
#[test]
fn fixtures_trigger_each_rule() {
    for (file, rule, expect_msg) in [
        ("l1_unwrap.rs", "L1", "non-test"),
        ("l1_allow_bare.rs", "L1", "justification"),
        ("l2_instant.rs", "L2", "zero timer syscalls"),
        ("l3_alloc.rs", "L3", "hotpath"),
        ("l4_phase.rs", "L4", "PHASE_NAMES"),
        ("l6_unsafe.rs", "L6", "SAFETY"),
    ] {
        let report = lint::run_paths(&[fixture(file)]);
        assert_eq!(
            report.findings.len(),
            1,
            "{file}: expected exactly one finding, got:\n{}",
            report.to_table()
        );
        let f = &report.findings[0];
        assert_eq!(f.rule, rule, "{file}: wrong rule: {}", f.message);
        assert!(
            f.message.contains(expect_msg),
            "{file}: message {:?} should mention {:?}",
            f.message,
            expect_msg
        );
    }
}

/// A justified allow silences the rule without any residual finding.
#[test]
fn justified_allow_is_clean() {
    let report = lint::run_paths(&[fixture("l1_allow.rs")]);
    assert!(
        report.is_clean(),
        "justified allow should produce no findings:\n{}",
        report.to_table()
    );
}

/// L5: a variant missing from the router's status mapping is reported
/// at its declaration line.
#[test]
fn missing_error_variant_is_reported() {
    let error_text =
        std::fs::read_to_string(fixture("l5_missing_variant/error.rs")).expect("fixture");
    let router_text =
        std::fs::read_to_string(fixture("l5_missing_variant/router.rs")).expect("fixture");
    let findings = rules::check_error_taxonomy(&error_text, &router_text, "error.rs");
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "L5");
    assert!(findings[0].message.contains("Error::Unmapped"));
    // the real taxonomy maps every variant, so the same check is silent
    let real_error =
        std::fs::read_to_string(repo_root().join("rust/src/error.rs")).expect("error.rs");
    let real_router = std::fs::read_to_string(repo_root().join("rust/src/serve/router.rs"))
        .expect("router.rs");
    let real = rules::check_error_taxonomy(&real_error, &real_router, "rust/src/error.rs");
    assert!(real.is_empty(), "{real:?}");
}

/// The JSON report is byte-stable across runs and sorted canonically.
#[test]
fn json_report_is_deterministic() {
    let paths: Vec<PathBuf> = ["l6_unsafe.rs", "l1_unwrap.rs", "l2_instant.rs"]
        .iter()
        .map(|f| fixture(f))
        .collect();
    let a = lint::run_paths(&paths).to_json();
    let b = lint::run_paths(&paths).to_json();
    assert_eq!(a, b);
    // findings come out sorted by (file, line, rule) regardless of the
    // order the files were linted in
    let l1 = a.find("\"L1\"").expect("L1 present");
    let l2 = a.find("\"L2\"").expect("L2 present");
    let l6 = a.find("\"L6\"").expect("L6 present");
    assert!(l1 < l2 && l2 < l6, "findings not in canonical order: {a}");
    // golden shape for a fixed single-file lint
    let vocab: Vec<String> = rules::FALLBACK_PHASES.iter().map(|s| s.to_string()).collect();
    let findings = lint::lint_source(
        "fixture.rs",
        "// lint: module serve::fixture\nfn f() { x.unwrap(); }\n",
        &vocab,
    );
    let report = lint::LintReport { findings, files_scanned: 1 }.canonicalize();
    assert_eq!(
        report.to_json(),
        "{\"count\":1,\"files_scanned\":1,\"findings\":[{\"rule\":\"L1\",\
         \"file\":\"fixture.rs\",\"line\":2,\"message\":\"`.unwrap()` in non-test \
         `serve::fixture` code: one panicked thread poisons shared state — use a \
         recovering/structured alternative (util::sync::LockExt, Result) or justify \
         with `lint: allow(L1)`\"}]}"
    );
}

/// The phase vocabulary is parsed from the real `obs::trace` source and
/// agrees with the exported constant.
#[test]
fn phase_vocabulary_parses_from_source() {
    let trace_text =
        std::fs::read_to_string(repo_root().join("rust/src/obs/trace.rs")).expect("trace.rs");
    let vocab = rules::parse_phase_names(&trace_text).expect("PHASE_NAMES found");
    let exported: Vec<String> =
        snapse::obs::PHASE_NAMES.iter().map(|s| s.to_string()).collect();
    assert_eq!(vocab, exported);
    for phase in ["run", "step", "fold", "checkout", "delta_cache"] {
        assert!(vocab.iter().any(|v| v == phase), "missing {phase}");
    }
}
