//! Parallel determinism: the pipelined engine must reproduce the serial
//! reference path byte-for-byte — same `allGenCk` (visited order), same
//! stop reason — at every worker count, in both search orders, bounded
//! and unbounded. This is the property that makes `--workers N` safe to
//! default on: parallelism may only change wall-clock time, never output.

use snapse::engine::{ExploreOptions, Explorer, SearchOrder, StopReason};
use snapse::snp::SnpSystem;

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn names(sys: &SnpSystem, opts: ExploreOptions) -> (Vec<String>, StopReason) {
    let rep = Explorer::new(sys, opts).run();
    (
        rep.visited.in_order().iter().map(|c| c.to_string()).collect(),
        rep.stop,
    )
}

fn opts(order: SearchOrder) -> ExploreOptions {
    match order {
        SearchOrder::BreadthFirst => ExploreOptions::breadth_first(),
        SearchOrder::DepthFirst => ExploreOptions::depth_first(),
    }
}

/// Every worker count must agree with workers=1 (the serial path).
fn assert_identical(sys: &SnpSystem, make: impl Fn() -> ExploreOptions, label: &str) {
    let (baseline, base_stop) = names(sys, make().workers(1));
    for w in WORKER_COUNTS {
        let (got, stop) = names(sys, make().workers(w));
        assert_eq!(got, baseline, "{label}: workers={w} changed allGenCk");
        assert_eq!(stop, base_stop, "{label}: workers={w} changed stop reason");
    }
}

#[test]
fn paper_pi_bfs_and_dfs_bounded_by_depth() {
    let sys = snapse::generators::paper_pi();
    for order in [SearchOrder::BreadthFirst, SearchOrder::DepthFirst] {
        assert_identical(&sys, || opts(order).max_depth(6), &format!("paper_pi {order:?}"));
    }
}

#[test]
fn paper_pi_bfs_and_dfs_bounded_by_configs() {
    // the exact config cap must truncate the very same prefix everywhere
    let sys = snapse::generators::paper_pi();
    for order in [SearchOrder::BreadthFirst, SearchOrder::DepthFirst] {
        assert_identical(
            &sys,
            || opts(order).max_configs(120),
            &format!("paper_pi cap {order:?}"),
        );
    }
}

#[test]
fn divisibility_checker_exhaustive_runs() {
    // finite systems, run to exhaustion — the strongest form of the
    // property (no bound masks a divergence)
    for (n, d) in [(24u64, 3u64), (36, 4), (35, 7), (10, 3)] {
        let sys = snapse::generators::divisibility_checker(n, d);
        for order in [SearchOrder::BreadthFirst, SearchOrder::DepthFirst] {
            assert_identical(&sys, || opts(order), &format!("div {n}/{d} {order:?}"));
        }
    }
}

#[test]
fn branching_workload_with_tiny_chunks() {
    // batch_cap 1 maximizes chunk count and reorder-buffer pressure
    let sys = snapse::generators::ring_with_branching(4, 2, 2);
    for order in [SearchOrder::BreadthFirst, SearchOrder::DepthFirst] {
        assert_identical(&sys, || opts(order).batch_cap(1), &format!("ring {order:?}"));
        assert_identical(&sys, || opts(order).batch_cap(7), &format!("ring b7 {order:?}"));
    }
}

#[test]
fn paper_prefix_reproduced_at_every_worker_count() {
    // the acceptance bar: the paper's §5 allGenCk prefix, byte-for-byte,
    // regardless of parallelism
    let sys = snapse::generators::paper_pi();
    let want = [
        "2-1-1", "2-1-2", "1-1-2", "2-1-3", "1-1-3", "2-0-2", "2-0-1", "2-1-4", "1-1-4",
        "2-0-3", "1-1-1", "0-1-2", "0-1-1",
    ];
    for w in WORKER_COUNTS {
        let (got, _) = names(&sys, ExploreOptions::breadth_first().max_depth(3).workers(w));
        assert_eq!(got, want, "workers={w}");
    }
}

#[test]
fn sparse_spiking_rows_identical_at_every_worker_count() {
    use snapse::compute::SpikeRepr;
    // The sparse CSR frontier path must reproduce the dense serial
    // reference byte-for-byte at 1/2/4/8 workers — on a rule-heavy
    // system where auto genuinely picks sparse, and on paper Π where
    // sparse is forced against auto's choice.
    let heavy = snapse::generators::rule_heavy(6, 12, 2);
    assert!(
        SpikeRepr::Auto.use_sparse(heavy.num_rules(), heavy.num_neurons()),
        "rule_heavy:6:12 must sit in auto's sparse regime"
    );
    for sys in [heavy, snapse::generators::paper_pi()] {
        for order in [SearchOrder::BreadthFirst, SearchOrder::DepthFirst] {
            let (dense_serial, dense_stop) =
                names(&sys, opts(order).max_configs(400).spike_repr(SpikeRepr::Dense));
            for w in WORKER_COUNTS {
                let (got, stop) = names(
                    &sys,
                    opts(order).max_configs(400).workers(w).spike_repr(SpikeRepr::Sparse),
                );
                assert_eq!(
                    got, dense_serial,
                    "{} {order:?}: sparse workers={w} diverged from dense serial",
                    sys.name
                );
                assert_eq!(stop, dense_stop, "{} {order:?} workers={w}", sys.name);
            }
        }
    }
}

#[test]
fn sparse_identical_to_dense_serial_on_every_builtin_system() {
    use snapse::compute::SpikeRepr;
    // The acceptance bar: `--spike-repr sparse` output equals the dense
    // serial reference on ALL builtin systems at 1/2/4/8 workers. The
    // spec strings below are exactly the CLI's builtin grammar, resolved
    // through the same `from_spec` path the CLI uses; infinite
    // generators are bounded by the config cap (enforced per-row, so the
    // truncated prefix is identical everywhere).
    let builtins = [
        "paper_pi",
        "nat_gen",
        "even_gen",
        "ring:4:2",
        "ring_branch:4:2:2",
        "wide_ring:8:3:2",
        "rule_heavy:6:12:2",
        "counter:4:3",
        "div:24:3",
        "adder:3",
        "random:7",
    ];
    for spec in builtins {
        let sys = snapse::generators::from_spec(spec)
            .expect("valid spec")
            .expect("builtin resolves");
        let (reference, ref_stop) = names(
            &sys,
            ExploreOptions::breadth_first().max_configs(200).spike_repr(SpikeRepr::Dense),
        );
        for w in WORKER_COUNTS {
            let (got, stop) = names(
                &sys,
                ExploreOptions::breadth_first()
                    .max_configs(200)
                    .workers(w)
                    .spike_repr(SpikeRepr::Sparse),
            );
            assert_eq!(got, reference, "{spec}: sparse workers={w} diverged");
            assert_eq!(stop, ref_stop, "{spec}: sparse workers={w} changed stop");
        }
    }
}

#[test]
fn delta_step_mode_identical_at_every_worker_count() {
    use snapse::compute::StepMode;
    // The delta-form hot path must reproduce the batch serial reference
    // byte-for-byte at 1/2/4/8 workers, both search orders, on systems
    // spanning the branching/rule-density spectrum.
    let systems = [
        snapse::generators::paper_pi(),
        snapse::generators::wide_ring(8, 3, 2),
        snapse::generators::rule_heavy(6, 12, 2),
    ];
    for sys in &systems {
        for order in [SearchOrder::BreadthFirst, SearchOrder::DepthFirst] {
            let (reference, ref_stop) =
                names(sys, opts(order).max_configs(400).step_mode(StepMode::Batch));
            for w in WORKER_COUNTS {
                let (got, stop) = names(
                    sys,
                    opts(order).max_configs(400).workers(w).step_mode(StepMode::Delta),
                );
                assert_eq!(
                    got, reference,
                    "{} {order:?}: delta workers={w} diverged from batch serial",
                    sys.name
                );
                assert_eq!(stop, ref_stop, "{} {order:?} workers={w}", sys.name);
            }
        }
    }
}

#[test]
fn delta_composes_with_sparse_rows() {
    use snapse::compute::{SpikeRepr, StepMode};
    // the two ablation axes together: CSR frontiers × delta stepping at
    // 4 workers vs the dense batch serial reference
    let sys = snapse::generators::rule_heavy(6, 12, 2);
    let (reference, _) = names(
        &sys,
        ExploreOptions::breadth_first()
            .max_configs(400)
            .spike_repr(SpikeRepr::Dense)
            .step_mode(StepMode::Batch),
    );
    for w in WORKER_COUNTS {
        let (got, _) = names(
            &sys,
            ExploreOptions::breadth_first()
                .max_configs(400)
                .workers(w)
                .spike_repr(SpikeRepr::Sparse)
                .step_mode(StepMode::Delta),
        );
        assert_eq!(got, reference, "sparse×delta workers={w}");
    }
}

#[test]
fn auto_step_mode_matches_forced_modes() {
    use snapse::compute::StepMode;
    let sys = snapse::generators::wide_ring(8, 3, 2);
    let (want, _) = names(&sys, ExploreOptions::breadth_first().max_configs(300));
    for mode in [StepMode::Batch, StepMode::Delta] {
        for w in [1usize, 4] {
            let (got, _) = names(
                &sys,
                ExploreOptions::breadth_first().max_configs(300).workers(w).step_mode(mode),
            );
            assert_eq!(got, want, "{mode:?} workers={w}");
        }
    }
    // stats report which mode actually ran: host pools are delta-native
    let rep = Explorer::new(&sys, ExploreOptions::breadth_first().max_configs(100)).run();
    assert_eq!(rep.stats.step_mode, "delta", "auto resolves delta on the host backend");
}

#[test]
fn auto_repr_matches_forced_reprs_on_rule_heavy() {
    use snapse::compute::SpikeRepr;
    let sys = snapse::generators::rule_heavy(6, 12, 2);
    let (want, _) = names(&sys, ExploreOptions::breadth_first().max_configs(300));
    for repr in [SpikeRepr::Dense, SpikeRepr::Sparse] {
        for w in [1usize, 4] {
            let (got, _) = names(
                &sys,
                ExploreOptions::breadth_first().max_configs(300).workers(w).spike_repr(repr),
            );
            assert_eq!(got, want, "{repr:?} workers={w}");
        }
    }
    // stats report which representation actually ran
    let rep = Explorer::new(&sys, ExploreOptions::breadth_first().max_configs(100)).run();
    assert_eq!(rep.stats.spike_repr, "sparse", "auto resolves sparse on rule_heavy");
}

#[test]
fn halting_configs_stable_on_uncapped_runs() {
    let sys = snapse::generators::divisibility_checker(30, 5);
    let base = Explorer::new(&sys, ExploreOptions::breadth_first()).run();
    for w in WORKER_COUNTS {
        let rep = Explorer::new(&sys, ExploreOptions::breadth_first().workers(w)).run();
        assert_eq!(rep.halting_configs, base.halting_configs, "workers={w}");
        assert_eq!(rep.depth_reached, base.depth_reached, "workers={w}");
    }
}
