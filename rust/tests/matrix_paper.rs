//! E5 — integration test: paper Definition 2 / eq. (1) / eq. (2).

use snapse::matrix::{build_matrix, TransitionMatrix};
use snapse::parser::parse_paper_files;

#[test]
fn eq1_matrix_from_all_construction_paths() {
    let expect: &[i64] = &[-1, 1, 1, -2, 1, 1, 1, -1, 1, 0, 0, -1, 0, 0, -2];
    // path 1: the programmatic generator
    let m1 = build_matrix(&snapse::generators::paper_pi());
    assert_eq!(m1.as_row_major(), expect);
    // path 2: the paper's three input files
    let input =
        parse_paper_files("2 1 1", "-1 1 1 -2 1 1 1 -1 1 0 0 -1 0 0 -2", "2 2 $ 1 $ 1 2")
            .unwrap();
    assert_eq!(input.matrix.as_row_major(), expect);
    let m2 = build_matrix(&input.to_system("from_files").unwrap());
    assert_eq!(m2.as_row_major(), expect);
    // path 3: the .snpl DSL
    let snpl = r#"
system pi
neuron s1 2
  rule >=2 / 1 -> 1
  rule >=2 / 2 -> 1
end
neuron s2 1
  rule >=1 / 1 -> 1
end
neuron s3 1 output
  rule >=1 / 1 -> 1
  rule >=2 / 2 -> 1
end
syn s1 s2 s3
syn s2 s1 s3
"#;
    let m3 = build_matrix(&snapse::parser::parse_snpl(snpl).unwrap());
    assert_eq!(m3.as_row_major(), expect);
}

#[test]
fn eq2_both_published_transitions() {
    let m = build_matrix(&snapse::generators::paper_pi());
    assert_eq!(m.step(&[2, 1, 1], &[1, 0, 1, 1, 0]).unwrap(), vec![2, 1, 2]);
    assert_eq!(m.step(&[2, 1, 1], &[0, 1, 1, 1, 0]).unwrap(), vec![1, 1, 2]);
}

#[test]
fn row_major_marshalling_is_eq3() {
    // eq. (3): the row-major flattening the paper feeds the GPU
    let m = build_matrix(&snapse::generators::paper_pi());
    let f32s = m.to_f32_row_major();
    assert_eq!(
        f32s,
        vec![-1., 1., 1., -2., 1., 1., 1., -1., 1., 0., 0., -1., 0., 0., -2.]
    );
}

#[test]
fn padding_to_square_preserves_steps() {
    // the paper pads non-square matrices with zeros (§6); verify zero
    // rows/columns never change results
    let m = build_matrix(&snapse::generators::paper_pi());
    let mut padded = TransitionMatrix::zeros(8, 8);
    for r in 0..5 {
        for c in 0..3 {
            padded.set(r, c, m.get(r, c));
        }
    }
    let out = padded.step(&[2, 1, 1, 0, 0, 0, 0, 0], &[1, 0, 1, 1, 0, 0, 0, 0]).unwrap();
    assert_eq!(&out[..3], &[2, 1, 2]);
    assert_eq!(&out[3..], &[0, 0, 0, 0, 0]);
}

#[test]
fn matrix_row_semantics_by_rule_kind() {
    // forgetting rules: negative diagonal, no production anywhere
    let sys = snapse::generators::nat_generator();
    let m = build_matrix(&sys);
    // rule (5) of nat_gen is a²→λ in σ3
    assert_eq!(m.row(4), &[0, 0, -2]);
}
