//! Coordinator end-to-end: determinism across worker counts, batch sizes
//! and window sizes; equivalence with the single-threaded explorer; and
//! budget behaviour under Ψ-explosions.

use snapse::coordinator::{BackendChoice, Coordinator, CoordinatorConfig};
use snapse::engine::{ExploreOptions, Explorer, StopReason};

fn run_names(sys: &snapse::snp::SnpSystem, cfg: CoordinatorConfig) -> Vec<String> {
    let mut coord = Coordinator::new(sys, cfg);
    let rep = coord.run().unwrap();
    rep.visited.in_order().iter().map(|c| c.to_string()).collect()
}

#[test]
fn identical_across_worker_counts_and_batch_targets() {
    let sys = snapse::generators::wide_ring(6, 3, 2);
    let baseline = run_names(&sys, CoordinatorConfig::default());
    for workers in [1usize, 2, 4, 16] {
        for batch in [1usize, 7, 64, 4096] {
            let got = run_names(
                &sys,
                CoordinatorConfig { workers, batch_target: batch, ..Default::default() },
            );
            assert_eq!(got, baseline, "workers={workers} batch={batch}");
        }
    }
}

#[test]
fn equals_single_threaded_explorer_on_generators() {
    for sys in [
        snapse::generators::paper_pi(),
        snapse::generators::nat_generator(),
        snapse::generators::counter_chain(5, 3),
        snapse::generators::ring(6, 2),
        snapse::generators::even_generator(),
    ] {
        let single =
            Explorer::new(&sys, ExploreOptions::breadth_first().max_configs(400)).run();
        let coord = run_names(
            &sys,
            CoordinatorConfig { max_configs: Some(400), workers: 3, ..Default::default() },
        );
        let single_names: Vec<String> =
            single.visited.in_order().iter().map(|c| c.to_string()).collect();
        // both stop at ≥400 configs; compare the common prefix
        let common = single_names.len().min(coord.len());
        assert!(common >= 300.min(single_names.len()), "{}", sys.name);
        assert_eq!(&single_names[..common], &coord[..common], "{}", sys.name);
    }
}

#[test]
fn psi_explosion_respects_budget_without_oom() {
    // Ψ(C0) = 2^14: one configuration fans out to 16384 children; the
    // windowed pipeline must stay within the budget's neighborhood.
    let sys = snapse::generators::ring_with_branching(14, 2, 2);
    let mut coord = Coordinator::new(
        &sys,
        CoordinatorConfig { max_configs: Some(1_000), ..Default::default() },
    );
    let rep = coord.run().unwrap();
    assert_eq!(rep.stop, StopReason::MaxConfigs);
    // one window may overshoot by its own fan-out, but not unboundedly
    assert!(rep.visited.len() < 40_000, "got {}", rep.visited.len());
}

#[test]
fn metrics_are_consistent() {
    let sys = snapse::generators::paper_pi();
    let mut coord = Coordinator::new(
        &sys,
        CoordinatorConfig { max_depth: Some(7), ..Default::default() },
    );
    let rep = coord.run().unwrap();
    let m = &rep.metrics;
    assert_eq!(m.levels.len(), 7);
    assert_eq!(m.total_new_configs() + 1, rep.visited.len() as u64, "+1 root");
    assert!(m.total_steps() >= m.total_new_configs());
    assert!(m.total_batches() >= m.levels.len() as u64 - 1);
    assert!(m.steps_per_sec() > 0.0);
    let table = m.render_table();
    assert_eq!(table.lines().count(), 2 + m.levels.len());
}

#[test]
fn halting_and_stop_reasons_match_explorer() {
    let sys = snapse::generators::counter_chain(4, 3);
    let single = Explorer::new(&sys, ExploreOptions::breadth_first()).run();
    let mut coord = Coordinator::new(&sys, CoordinatorConfig::default());
    let rep = coord.run().unwrap();
    assert_eq!(rep.stop, single.stop);
    assert_eq!(rep.halting, single.halting_configs);
}

#[test]
fn xla_backend_choice_reports_missing_artifacts_cleanly() {
    let sys = snapse::generators::paper_pi();
    let mut coord = Coordinator::new(
        &sys,
        CoordinatorConfig {
            backend: BackendChoice::Xla { artifacts: "/definitely/missing".into() },
            ..Default::default()
        },
    );
    let err = coord.run().unwrap_err();
    assert!(err.to_string().contains("io error") || err.to_string().contains("artifact"));
}
