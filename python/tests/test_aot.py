"""AOT path tests: lowering produces loadable HLO text + a sane manifest."""

import json
import os

import numpy as np

from compile import aot
from compile.kernels.snp_step import plan_tiles


def test_lower_step_emits_hlo_text():
    text = aot.lower_step(5, 3, 2)
    assert text.startswith("HloModule")
    # entry computation must take the three f32 arrays at the right shapes
    assert "f32[2,5]" in text, "S (B,R)"
    assert "f32[5,3]" in text, "M (R,N)"
    assert "f32[2,3]" in text, "C (B,N)"
    # lowered with return_tuple=True → tuple root
    assert "(f32[2,3]" in text


def test_matmul_variant_also_lowers():
    text = aot.lower_step(5, 3, 1, variant="matmul")
    assert text.startswith("HloModule")
    assert "dot(" in text


def test_build_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build(out, shapes=[(5, 3)], batches=[1, 4])
    steps = [e for e in manifest["entries"] if e["kind"] == "step"]
    replays = [e for e in manifest["entries"] if e["kind"] == "replay"]
    assert len(steps) == 2
    assert len(replays) == len(aot.REPLAY_KS), "replay programs always emitted"
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    for e in on_disk["entries"]:
        path = os.path.join(out, e["path"])
        assert os.path.exists(path)
        assert e["vmem_bytes"] > 0
    for e in steps:
        assert e["flops"] == 2 * e["b"] * e["r"] * e["n"] + e["b"] * e["n"]
    for e in replays:
        assert e["k"] in aot.REPLAY_KS


def test_tile_plan_structure():
    p = plan_tiles(512, 5, 3)
    assert p.tb * p.grid[0] == 512
    assert p.tn * p.grid[1] == 3
    assert p.vmem_bytes <= 16 * 1024 * 1024, "fits the TPU VMEM budget"
    # MXU bound is a fraction
    assert 0 < p.mxu_utilization_bound <= 1.0
    # bigger tiles fill the MXU better
    assert plan_tiles(128, 128, 128).mxu_utilization_bound == 1.0


def test_default_grid_has_paper_shape():
    assert (5, 3) in aot.DEFAULT_SHAPES
