"""L1 kernel vs pure-jnp/numpy oracle — the core correctness signal.

Hypothesis sweeps shapes and values; exactness is asserted (counts are
small integers, f32-exact)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import masked_step_ref, step_ref, step_ref_numpy
from compile.kernels.snp_step import (
    masked_step_pallas,
    plan_tiles,
    step_pallas,
)

# The paper's Π matrix (eq. (1)).
M_PI = np.array(
    [[-1, 1, 1], [-2, 1, 1], [1, -1, 1], [0, 0, -1], [0, 0, -2]],
    dtype=np.float32,
)


def _random_case(rng, b, r, n):
    s = (rng.random((b, r)) < 0.4).astype(np.float32)
    m = rng.integers(-4, 5, size=(r, n)).astype(np.float32)
    c = rng.integers(0, 50, size=(b, n)).astype(np.float32)
    return s, m, c


def test_paper_eq2_single_row():
    s = np.array([[1, 0, 1, 1, 0]], dtype=np.float32)
    c = np.array([[2, 1, 1]], dtype=np.float32)
    out = np.asarray(step_pallas(jnp.asarray(s), jnp.asarray(M_PI), jnp.asarray(c)))
    np.testing.assert_array_equal(out, [[2, 1, 2]])


def test_paper_eq2_second_vector():
    s = np.array([[0, 1, 1, 1, 0]], dtype=np.float32)
    c = np.array([[2, 1, 1]], dtype=np.float32)
    out = np.asarray(step_pallas(jnp.asarray(s), jnp.asarray(M_PI), jnp.asarray(c)))
    np.testing.assert_array_equal(out, [[1, 1, 2]])


def test_zero_spiking_vector_is_identity():
    s = np.zeros((4, 5), dtype=np.float32)
    c = np.arange(12, dtype=np.float32).reshape(4, 3)
    out = np.asarray(step_pallas(jnp.asarray(s), jnp.asarray(M_PI), jnp.asarray(c)))
    np.testing.assert_array_equal(out, c)


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 48),
    r=st.integers(1, 24),
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31),
)
def test_kernel_matches_ref_random_shapes(b, r, n, seed):
    rng = np.random.default_rng(seed)
    s, m, c = _random_case(rng, b, r, n)
    got = np.asarray(step_pallas(jnp.asarray(s), jnp.asarray(m), jnp.asarray(c)))
    want_jnp = np.asarray(step_ref(jnp.asarray(s), jnp.asarray(m), jnp.asarray(c)))
    want_int = step_ref_numpy(s, m, c)
    np.testing.assert_array_equal(got, want_jnp)
    np.testing.assert_array_equal(got.astype(np.int64), want_int)


@settings(max_examples=20, deadline=None)
@given(
    bpow=st.integers(0, 7),
    npow=st.integers(0, 5),
    seed=st.integers(0, 2**31),
)
def test_kernel_tiled_pow2_shapes(bpow, npow, seed):
    """Power-of-two shapes exercise the real multi-tile grid path."""
    b, n, r = 2**bpow, 2**npow, 8
    rng = np.random.default_rng(seed)
    s, m, c = _random_case(rng, b, r, n)
    got = np.asarray(step_pallas(jnp.asarray(s), jnp.asarray(m), jnp.asarray(c)))
    np.testing.assert_array_equal(got.astype(np.int64), step_ref_numpy(s, m, c))
    plan = plan_tiles(b, r, n)
    assert plan.grid[0] * plan.tb == b
    assert plan.grid[1] * plan.tn == n


def test_counts_exact_up_to_large_values():
    # f32 exactness claim: counts up to 2^20 survive the round trip
    s = np.ones((1, 1), dtype=np.float32)
    m = np.array([[1]], dtype=np.float32)
    c = np.array([[float(2**20)]], dtype=np.float32)
    out = np.asarray(step_pallas(jnp.asarray(s), jnp.asarray(m), jnp.asarray(c)))
    assert out[0, 0] == 2**20 + 1


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 16), seed=st.integers(0, 2**31))
def test_masked_step_matches_ref(b, seed):
    rng = np.random.default_rng(seed)
    r, n = 5, 3
    s = (rng.random((b, r)) < 0.5).astype(np.float32)
    c = rng.integers(0, 6, size=(b, n)).astype(np.float32)
    guard_min = np.array([2, 2, 1, 1, 2], dtype=np.float32)
    exact = np.array([0, 0, 0, 0, 0], dtype=np.float32)
    got = np.asarray(
        masked_step_pallas(
            jnp.asarray(s), jnp.asarray(M_PI), jnp.asarray(c),
            jnp.asarray(guard_min), jnp.asarray(exact),
        )
    )
    want = masked_step_ref(s, M_PI, c, guard_min, exact)
    np.testing.assert_array_equal(got, want.astype(np.float32))


def test_masked_step_zeroes_inapplicable_rules():
    # C = [1,1,1]: rules (1),(2) need ≥2 spikes in σ1 → their S bits drop
    s = np.array([[1, 0, 1, 1, 0]], dtype=np.float32)
    c = np.array([[1, 1, 1]], dtype=np.float32)
    guard_min = np.array([2, 2, 1, 1, 2], dtype=np.float32)
    exact = np.zeros(5, dtype=np.float32)
    got = np.asarray(
        masked_step_pallas(
            jnp.asarray(s), jnp.asarray(M_PI), jnp.asarray(c),
            jnp.asarray(guard_min), jnp.asarray(exact),
        )
    )
    # only rules (3) and (4) survive: [1,1,1] + [1,-1,1] + [0,0,-1]
    np.testing.assert_array_equal(got, [[2, 0, 1]])


def test_shape_mismatch_raises():
    s = jnp.zeros((2, 5))
    m = jnp.zeros((4, 3))  # wrong R
    c = jnp.zeros((2, 3))
    with pytest.raises(AssertionError):
        step_pallas(s, m, c)
