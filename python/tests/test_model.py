"""L2 model tests: variants agree, multi-step scan composes."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import step_ref_numpy

M_PI = np.array(
    [[-1, 1, 1], [-2, 1, 1], [1, -1, 1], [0, 0, -1], [0, 0, -2]],
    dtype=np.float32,
)


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 16), seed=st.integers(0, 2**31))
def test_step_and_matmul_variant_agree(b, seed):
    rng = np.random.default_rng(seed)
    s = (rng.random((b, 5)) < 0.4).astype(np.float32)
    c = rng.integers(0, 10, size=(b, 3)).astype(np.float32)
    (a,) = model.step(jnp.asarray(s), jnp.asarray(M_PI), jnp.asarray(c))
    (bb,) = model.step_matmul(jnp.asarray(s), jnp.asarray(M_PI), jnp.asarray(c))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


def test_multi_step_equals_iterated_single_steps():
    rng = np.random.default_rng(0)
    k, b = 6, 4
    s_seq = (rng.random((k, b, 5)) < 0.3).astype(np.float32)
    c = rng.integers(0, 10, size=(b, 3)).astype(np.float32)
    (scan_out,) = model.multi_step(jnp.asarray(s_seq), jnp.asarray(M_PI), jnp.asarray(c))
    cur = c.astype(np.int64)
    for i in range(k):
        cur = step_ref_numpy(s_seq[i], M_PI, cur.astype(np.float32))
    np.testing.assert_array_equal(np.asarray(scan_out).astype(np.int64), cur)


def test_step_is_jittable_and_stable_under_jit():
    s = jnp.asarray(np.eye(5, dtype=np.float32)[:2])
    c = jnp.asarray(np.full((2, 3), 5, dtype=np.float32))
    m = jnp.asarray(M_PI)
    (eager,) = model.step(s, m, c)
    (jitted,) = jax.jit(model.step)(s, m, c)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))
