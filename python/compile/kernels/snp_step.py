"""Layer 1 — the Pallas transition-step kernel.

Computes the paper's eq. (2) for a whole frontier batch in one fused
kernel::

    C' = C + S · M        S: (B, R) 0/1,  M: (R, N),  C/C': (B, N)

CUDA → TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper maps one
GPU thread per product element and reduces; on TPU the natural unit is the
MXU systolic array, so the whole batch is a single tiled matmul fused with
the `C +` add (one VMEM round trip, no host staging between multiply and
add — the paper did the add in a second kernel pass).

Tiling: the batch (B) and neuron (N) axes are gridded into (TB, TN) VMEM
tiles; the rule axis (R) is kept resident per tile pair and accumulated in
one dot. `plan_tiles` reports the VMEM footprint so `aot.py --report` can
check it against the ~16 MiB/core budget of a real TPU.

The kernel MUST run with ``interpret=True`` here: real TPU lowering emits
a Mosaic custom-call the CPU PJRT plugin cannot execute. The lowered HLO
is therefore plain XLA ops — identical numerics, same fusion structure.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclass(frozen=True)
class TilePlan:
    """Tile sizes and the derived VMEM/MXU estimates for one shape."""

    b: int
    r: int
    n: int
    tb: int  # batch-axis tile
    tn: int  # neuron-axis tile
    grid: tuple

    @property
    def vmem_bytes(self) -> int:
        """f32 bytes resident per grid step: S-tile + M-tile + C-tile + out."""
        s_tile = self.tb * self.r
        m_tile = self.r * self.tn
        c_tile = self.tb * self.tn
        return 4 * (s_tile + m_tile + 2 * c_tile)

    @property
    def flops(self) -> int:
        """Matmul core: 2·B·R·N plus the B·N add."""
        return 2 * self.b * self.r * self.n + self.b * self.n

    @property
    def mxu_utilization_bound(self) -> float:
        """Fraction of an (128×128) MXU pass the tile shapes can fill —
        the structural ceiling on utilization for this shape (small R or N
        underfill the systolic array)."""
        fill_k = min(self.r, 128) / 128.0
        fill_n = min(self.tn, 128) / 128.0
        fill_m = min(self.tb, 128) / 128.0
        return fill_m * fill_k * fill_n


VMEM_BUDGET = 16 * 1024 * 1024  # ≈ one TPU core's VMEM


def plan_tiles(b: int, r: int, n: int, vmem_budget: int = VMEM_BUDGET) -> TilePlan:
    """Choose (TB, TN) tiles.

    Prefer whole-array tiles when the working set fits the VMEM budget —
    a single grid step avoids the sequential grid loop entirely (measured
    1.1–1.6× on CPU-PJRT, see EXPERIMENTS.md §Perf iteration 3, and one
    MXU pass per call on TPU). Otherwise fall back to the largest
    power-of-two divisor tiles that fit.
    """
    full = TilePlan(b=b, r=r, n=n, tb=b, tn=n, grid=(1, 1))
    if full.vmem_bytes <= vmem_budget:
        return full

    def tiles_of(dim: int):
        t = 1
        out = [1]
        while t * 2 <= dim and dim % (t * 2) == 0:
            t *= 2
            out.append(t)
        return out

    best = None
    for tb in tiles_of(b):
        for tn in tiles_of(n):
            p = TilePlan(b=b, r=r, n=n, tb=tb, tn=tn, grid=(b // tb, n // tn))
            if p.vmem_bytes <= vmem_budget:
                score = (tb * tn, p.mxu_utilization_bound)
                if best is None or score > best[0]:
                    best = (score, p)
    assert best is not None, f"no tile of ({b},{r},{n}) fits {vmem_budget}B VMEM"
    return best[1]


def _step_kernel(s_ref, m_ref, c_ref, out_ref):
    """One (TB, TN) tile: out = c + s @ m, accumulated in f32."""
    s = s_ref[...]
    m = m_ref[...]
    c = c_ref[...]
    # jnp.dot on (TB, R) × (R, TN) lowers to the MXU on real TPUs;
    # preferred_element_type pins the f32 accumulator (counts are exact).
    acc = jnp.dot(s, m, preferred_element_type=jnp.float32)
    out_ref[...] = c + acc


@functools.partial(jax.jit, static_argnames=())
def step_reference_shape(s, m, c):
    """Non-pallas stand-in used only for shape inference in tests."""
    return c + s @ m


def step_pallas(s: jax.Array, m: jax.Array, c: jax.Array) -> jax.Array:
    """The fused transition step as a Pallas call.

    Arguments are f32 arrays: ``s`` (B, R), ``m`` (R, N), ``c`` (B, N).
    Returns ``c + s @ m`` with shape (B, N).
    """
    b, r = s.shape
    r2, n = m.shape
    assert r == r2, f"rule-axis mismatch {r} vs {r2}"
    assert c.shape == (b, n), f"config shape {c.shape} != {(b, n)}"
    plan = plan_tiles(b, r, n)
    return pl.pallas_call(
        _step_kernel,
        grid=plan.grid,
        in_specs=[
            # S: tile the batch axis, keep all R resident
            pl.BlockSpec((plan.tb, r), lambda i, j: (i, 0)),
            # M: keep all R resident, tile the neuron axis
            pl.BlockSpec((r, plan.tn), lambda i, j: (0, j)),
            # C: tile both
            pl.BlockSpec((plan.tb, plan.tn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((plan.tb, plan.tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(s, m, c)


def masked_step_pallas(s, m, c, guard_min, guard_exact_mask):
    """Extension kernel (fused applicability recheck, E8 ablation).

    Re-validates the spiking vector on-device before applying it:
    a row of S is zeroed wherever its rule's guard is violated by C —
    ``k ≥ guard_min[r]`` for threshold rules, ``k == guard_min[r]`` when
    ``guard_exact_mask[r] == 1``. `owner` one-hot (R, N) maps rules to
    their neuron, reusing M's sign structure: owner = (M < 0).

    This is VPU elementwise work fused ahead of the MXU matmul — the part
    the paper's host (Python) did between kernel launches.
    """
    owner = (m < 0).astype(jnp.float32)  # (R, N): rule r consumes in its neuron
    # spike count of each rule's neuron, per batch row: (B, R)
    k = c @ owner.T
    ge = k >= guard_min[None, :]
    eq = k == guard_min[None, :]
    ok = jnp.where(guard_exact_mask[None, :] > 0, eq, ge)
    s_ok = s * ok.astype(jnp.float32)
    return step_pallas(s_ok, m, c)
