"""Pure-jnp oracle for the step kernel — the CORE correctness signal.

Everything the Pallas kernel (and the lowered HLO the Rust runtime
executes) computes must match this, elementwise, exactly (f32 counts are
integers far below 2**24).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def step_ref(s, m, c):
    """C' = C + S·M in plain jnp."""
    return c + jnp.dot(s, m, preferred_element_type=jnp.float32)


def step_ref_numpy(s: np.ndarray, m: np.ndarray, c: np.ndarray) -> np.ndarray:
    """Same oracle in int64 numpy — the no-float ground truth."""
    return c.astype(np.int64) + s.astype(np.int64) @ m.astype(np.int64)


def masked_step_ref(s, m, c, guard_min, guard_exact_mask):
    """Oracle for the fused-applicability variant."""
    owner = (np.asarray(m) < 0).astype(np.float32)
    k = np.asarray(c) @ owner.T
    ge = k >= np.asarray(guard_min)[None, :]
    eq = k == np.asarray(guard_min)[None, :]
    ok = np.where(np.asarray(guard_exact_mask)[None, :] > 0, eq, ge)
    s_ok = np.asarray(s) * ok.astype(np.float32)
    return np.asarray(c) + s_ok @ np.asarray(m)
