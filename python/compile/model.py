"""Layer 2 — the JAX step model lowered to the AOT artifacts.

The "model" of this paper is the frontier transition program: given a
batch of spiking vectors S, the system matrix M, and the batch's current
configurations C, produce the next configurations. `step` calls the L1
Pallas kernel so both lower into the same HLO module.

Variants:

- ``step``          — the production program (fused Pallas kernel).
- ``step_matmul``   — plain-XLA variant (no Pallas), ablation baseline.
- ``step_masked``   — step fused with on-device guard rechecking (E8).
- ``multi_step``    — K chained steps with a shared M (scan; used to show
  XLA keeps M device-resident across steps — the round-trip cost the
  paper's §3.1 worries about disappears under AOT).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.snp_step import masked_step_pallas, step_pallas


def step(s, m, c):
    """C' = C + S·M (Pallas kernel). All args f32."""
    return (step_pallas(s, m, c),)


def step_matmul(s, m, c):
    """Ablation: the same computation as a bare XLA dot+add."""
    return (c + jnp.dot(s, m, preferred_element_type=jnp.float32),)


def step_masked(s, m, c, guard_min, guard_exact_mask):
    """Step with fused on-device applicability recheck."""
    return (masked_step_pallas(s, m, c, guard_min, guard_exact_mask),)


def multi_step(s_seq, m, c):
    """Apply K spiking vectors in sequence: s_seq is (K, B, R).

    M stays device-resident across the scan — one upload per call instead
    of per step (the paper's host↔device traffic concern).
    """

    def body(carry, s):
        nxt = step_pallas(s, m, carry)
        return nxt, None

    final, _ = jax.lax.scan(body, c, s_seq)
    return (final,)
