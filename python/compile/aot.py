"""AOT lowering: JAX/Pallas step programs → HLO text + manifest.

Run once by ``make artifacts``; the Rust runtime consumes the output and
Python never appears on the request path again.

Interchange is HLO **text**, not a serialized ``HloModuleProto``: jax
≥ 0.5 emits 64-bit instruction ids that the published xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids cleanly
(see /opt/xla-example/README.md).

Usage::

    python -m compile.aot --out-dir ../artifacts            # default grid
    python -m compile.aot --out-dir ../artifacts --report   # VMEM/MXU table
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.snp_step import plan_tiles

# Shapes lowered by default:
#  - the paper's Π: (R, N) = (5, 3);
#  - shipped generators' exact shapes (ring/counter/etc. used in examples);
#  - a generic power-of-two grid for arbitrary systems via padding.
DEFAULT_SHAPES = [
    (5, 3),  # paper_pi / nat_gen (E1, E2, E5)
    (4, 4),  # even_gen (4 rules, 3 neurons → padded grid handles; exact for ring:4:1? no)
    (8, 8),
    (16, 16),
    (32, 32),
    (64, 64),
    (128, 128),
]
DEFAULT_BATCHES = [1, 8, 32, 128, 512]
# K-step replay programs (B = 1), lowered for the paper shape.
REPLAY_SHAPES = [(5, 3)]
REPLAY_KS = [8, 32, 128]
# Big shapes get a trimmed batch ladder to bound artifact count/compile RAM.
MAX_ELEMS = 512 * 128  # cap B·N per artifact


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_step(r: int, n: int, b: int, variant: str = "pallas") -> str:
    """Lower one step program at shape (B, R, N)."""
    s_spec = jax.ShapeDtypeStruct((b, r), jnp.float32)
    m_spec = jax.ShapeDtypeStruct((r, n), jnp.float32)
    c_spec = jax.ShapeDtypeStruct((b, n), jnp.float32)
    fn = model.step if variant == "pallas" else model.step_matmul
    lowered = jax.jit(fn).lower(s_spec, m_spec, c_spec)
    return to_hlo_text(lowered)


def lower_replay(r: int, n: int, k: int) -> str:
    """Lower a K-step replay program (lax.scan over the Pallas kernel,
    B = 1): verifies recorded walks on-device with ONE dispatch for the
    whole trajectory — M is uploaded once and stays resident across all K
    steps inside the program itself."""
    s_spec = jax.ShapeDtypeStruct((k, 1, r), jnp.float32)
    m_spec = jax.ShapeDtypeStruct((r, n), jnp.float32)
    c_spec = jax.ShapeDtypeStruct((1, n), jnp.float32)
    lowered = jax.jit(model.multi_step).lower(s_spec, m_spec, c_spec)
    return to_hlo_text(lowered)


def build(out_dir: str, shapes, batches, variant: str = "pallas") -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for r, n in shapes:
        for b in batches:
            if b * n > MAX_ELEMS and b > 1:
                continue
            name = f"step_r{r}_n{n}_b{b}.hlo.txt"
            path = os.path.join(out_dir, name)
            text = lower_step(r, n, b, variant)
            with open(path, "w") as f:
                f.write(text)
            plan = plan_tiles(b, r, n)
            entries.append(
                {
                    "kind": "step",
                    "r": r,
                    "n": n,
                    "b": b,
                    "path": name,
                    "variant": variant,
                    "vmem_bytes": plan.vmem_bytes,
                    "flops": plan.flops,
                    "mxu_bound": round(plan.mxu_utilization_bound, 4),
                }
            )
            print(f"  wrote {name} ({len(text)} chars)")
    # replay programs (scan over K steps, B = 1)
    for r, n in REPLAY_SHAPES:
        for k in REPLAY_KS:
            name = f"replay_r{r}_n{n}_k{k}.hlo.txt"
            with open(os.path.join(out_dir, name), "w") as f:
                f.write(lower_replay(r, n, k))
            plan = plan_tiles(1, r, n)
            entries.append(
                {
                    "kind": "replay",
                    "r": r,
                    "n": n,
                    "b": 1,
                    "k": k,
                    "path": name,
                    "variant": "pallas-scan",
                    "vmem_bytes": plan.vmem_bytes,
                    "flops": plan.flops * k,
                }
            )
            print(f"  wrote {name}")
    manifest = {"version": 1, "entries": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(entries)} artifacts in {out_dir}")
    return manifest


def report(shapes, batches) -> None:
    """Print the per-shape VMEM footprint / MXU-bound table (DESIGN §Perf).

    interpret=True wallclock is NOT a TPU proxy; these structural numbers
    are what we optimize (tile residency, MXU fill)."""
    print(f"{'shape (B,R,N)':>18} {'tiles':>10} {'VMEM':>10} {'FLOPs':>12} {'MXU bound':>10}")
    for r, n in shapes:
        for b in batches:
            if b * n > MAX_ELEMS and b > 1:
                continue
            p = plan_tiles(b, r, n)
            print(
                f"{f'({b},{r},{n})':>18} {f'{p.tb}x{p.tn}':>10} "
                f"{p.vmem_bytes:>9}B {p.flops:>12} {p.mxu_utilization_bound:>10.3f}"
            )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variant", default="pallas", choices=["pallas", "matmul"])
    ap.add_argument("--shapes", default=None, help="comma list rxn, e.g. 5x3,16x16")
    ap.add_argument("--batches", default=None, help="comma list, e.g. 1,8,32")
    ap.add_argument("--report", action="store_true", help="print VMEM/MXU table only")
    args = ap.parse_args()

    shapes = DEFAULT_SHAPES
    if args.shapes:
        shapes = [tuple(int(x) for x in s.split("x")) for s in args.shapes.split(",")]
    batches = DEFAULT_BATCHES
    if args.batches:
        batches = [int(x) for x in args.batches.split(",")]

    if args.report:
        report(shapes, batches)
        return
    build(args.out_dir, shapes, batches, args.variant)


if __name__ == "__main__":
    sys.exit(main())
